//! The runtime Energy Manager (paper §2.1): tracks storage state and the
//! harvesting rate, and supplies the scheduler's energy terms — E_curr,
//! E_man, E_opt and the offline-estimated η — used by ζ_I (Eq. 7).

use super::capacitor::Capacitor;
use super::harvester::Harvester;

#[derive(Clone, Debug)]
pub struct EnergyManager {
    pub capacitor: Capacitor,
    pub harvester: Harvester,
    /// Offline-estimated η-factor of this deployment (paper §3.3).
    pub eta: f64,
    /// Minimum energy to power up and run one atomic fragment (set at
    /// compile time from the cost model's max fragment energy).
    pub e_man_mj: f64,
    /// Threshold for scheduling optional units; defaults to a full
    /// capacitor ("once the capacitor is full the excess gets wasted").
    pub e_opt_mj: f64,
    /// Total harvested energy (bookkeeping for reports).
    pub harvested_mj: f64,
    /// Number of MCU reboots observed.
    pub reboots: u64,
    was_on: bool,
}

impl EnergyManager {
    pub fn new(capacitor: Capacitor, harvester: Harvester, eta: f64, e_man_mj: f64) -> Self {
        // Default E_opt: "the energy required to fill up the capacitor"
        // (§2.2) — optional units should only absorb energy that would
        // otherwise be *wasted*. The ζ_I gate is η·E_curr ≥ E_opt, so with
        // E_opt = 0.7 × usable capacity a predictable harvester (η ≥ 0.7)
        // passes exactly when the capacitor is essentially full (waste
        // imminent), while η = 0.51 / 0.38 never pass — matching §8.5's
        // "with low η ... no optional units are executed".
        let usable = capacitor.capacity_mj() - capacitor.floor_mj();
        EnergyManager {
            capacitor,
            harvester,
            eta,
            e_man_mj,
            e_opt_mj: usable * 0.7,
            harvested_mj: 0.0,
            reboots: 0,
            was_on: false,
        }
    }

    /// Developer override (paper §2.2 discusses the failure modes of both
    /// extremes; the API exists for exactly that experiment).
    pub fn set_e_opt(&mut self, e_opt_mj: f64) {
        self.e_opt_mj = e_opt_mj;
    }

    /// Advance time: harvest and charge; track reboots.
    pub fn tick(&mut self, dt_ms: f64) {
        let p = self.harvester.step(dt_ms);
        self.harvested_mj += p * dt_ms * 1e-3; // mW·ms·1e-3 = mJ
        self.capacitor.charge(p, dt_ms);
        let on = self.capacitor.mcu_on();
        if on && !self.was_on {
            self.reboots += 1;
        }
        self.was_on = on;
    }

    /// One tick of the off-phase fast path: succeeds iff the MCU is off
    /// AND the harvester can take a zero-power in-window tick
    /// ([`Harvester::off_tick`]). On success the manager state is
    /// **bitwise identical** to what `tick(dt_ms)` would have produced:
    /// harvesting 0 mW adds exactly 0.0 mJ (`harvested_mj` and the
    /// capacitor are unchanged bit for bit, and `Capacitor::charge(0.0, _)`
    /// cannot move the MCU state), and the only observation `tick` would
    /// have recorded is the off state itself — which is why `was_on` must
    /// still be cleared here, or a brown-out that happened via a *draw*
    /// (not a tick) would leave `was_on` stale and a later boot would
    /// miss a reboot count. On failure nothing advances; take `tick`.
    #[inline]
    pub fn off_tick(&mut self, dt_ms: f64) -> bool {
        if self.capacitor.mcu_on() || !self.harvester.off_tick(dt_ms) {
            return false;
        }
        self.was_on = false;
        true
    }

    /// Bulk replay of `n` dark (zero-harvest, in-window) ticks for the
    /// event-driven engine core. Equivalent bitwise to `n` calls of either
    /// [`EnergyManager::tick`] (MCU on — the engine drains the capacitor
    /// separately via `Capacitor::fast_forward_idle_drain`) or
    /// [`EnergyManager::off_tick`] (MCU off), because a dark tick harvests
    /// exactly 0 mW: `harvested_mj += 0.0` and `Capacitor::charge(0.0, _)`
    /// are bitwise identities on non-negative accumulators, leaving only
    /// the harvester window clock — replayed exactly — and the
    /// `was_on`/reboot observation, which is constant after the first tick
    /// (the MCU state cannot change without charge or drain crossing a
    /// threshold, which the caller's budget excludes).
    pub fn fast_forward_dark(&mut self, n: u64, dt_ms: f64) {
        if n == 0 {
            return;
        }
        let on = self.capacitor.mcu_on();
        if on && !self.was_on {
            // What the first naive `tick` would have observed (e.g. a
            // pre-t0 precharge boot never seen by a tick yet).
            self.reboots += 1;
        }
        self.was_on = on;
        self.harvester.fast_forward_dark(n, dt_ms);
    }

    /// Conservative ticks-until-voltage-crossing predictor: how many idle
    /// ticks draining `drain_mj_per_tick` can run while the capacitor
    /// provably stays **above** voltage `v` — the JIT-trigger leg of the
    /// engine's next-event budget. Pads the algebraic E(V) inverse by two
    /// drain quanta so the rounded-sqrt voltage compare the real trigger
    /// uses cannot disagree within the admitted ticks.
    pub fn ticks_above_voltage(&self, v: f64, drain_mj_per_tick: f64) -> u64 {
        let guard = self.capacitor.energy_at_voltage_mj(v) + 2.0 * drain_mj_per_tick;
        self.capacitor.idle_ticks_above(guard, drain_mj_per_tick)
    }

    /// The scheduler's E_curr: usable stored energy.
    pub fn e_curr(&self) -> f64 {
        self.capacitor.usable_mj()
    }

    /// ζ_I regime test (Eq. 7): optional units are schedulable iff
    /// η · E_curr ≥ E_opt.
    pub fn optional_allowed(&self) -> bool {
        self.eta * self.e_curr() >= self.e_opt_mj
    }

    /// Mandatory units need at least one fragment's worth of energy.
    pub fn mandatory_allowed(&self) -> bool {
        self.capacitor.mcu_on() && self.e_curr() >= self.e_man_mj
    }

    /// JIT-checkpoint trigger (Hibernus/QuickRecall idiom): true when the
    /// capacitor has sagged to `threshold_v` or below while the MCU is
    /// still up — the last safe moment to commit volatile progress before
    /// an impending brown-out. Consumed by `CommitPolicy::JitVoltage`.
    pub fn jit_voltage_trigger(&self, threshold_v: f64) -> bool {
        self.capacitor.mcu_on() && self.capacitor.voltage() <= threshold_v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::HarvesterKind;

    fn mgr(eta: f64) -> EnergyManager {
        EnergyManager::new(
            Capacitor::standard(),
            Harvester::persistent(600.0),
            eta,
            0.05,
        )
    }

    #[test]
    fn charges_and_boots() {
        let mut m = mgr(1.0);
        assert!(!m.mandatory_allowed());
        for _ in 0..10_000 {
            m.tick(100.0);
        }
        assert!(m.mandatory_allowed());
        assert_eq!(m.reboots, 1);
        assert!(m.harvested_mj > 0.0);
    }

    #[test]
    fn optional_gated_by_eta_times_ecurr() {
        // Full capacitor, persistent source: optional allowed at η=1.
        let mut m = mgr(1.0);
        for _ in 0..100_000 {
            m.tick(100.0);
        }
        assert!(m.capacitor.is_full());
        assert!(m.optional_allowed());
        // Same storage but unpredictable harvester (η≈0): optional blocked.
        let mut m0 = mgr(0.05);
        for _ in 0..100_000 {
            m0.tick(100.0);
        }
        assert!(!m0.optional_allowed());
    }

    #[test]
    fn e_opt_override_changes_gate() {
        let mut m = mgr(0.5);
        for _ in 0..100_000 {
            m.tick(100.0);
        }
        assert!(!m.optional_allowed()); // 0.5 * full < full
        m.set_e_opt(m.e_curr() * 0.4);
        assert!(m.optional_allowed());
    }

    #[test]
    fn jit_trigger_fires_only_near_brownout_while_on() {
        let mut m = mgr(1.0);
        // Off and empty: no trigger (nothing to save, nothing running).
        assert!(!m.jit_voltage_trigger(2.0));
        for _ in 0..100_000 {
            m.tick(100.0);
        }
        // Full capacitor at 3.3 V: above any sensible threshold.
        assert!(!m.jit_voltage_trigger(2.0));
        // Drain down toward v_off = 1.9: the trigger fires before the
        // MCU browns out.
        let mut fired = false;
        while m.capacitor.mcu_on() {
            fired = fired || m.jit_voltage_trigger(2.0);
            let _ = m.capacitor.draw(1.0);
        }
        assert!(fired, "trigger never fired on the way down");
    }

    /// The manager-level fast-path contract: a walk that takes `off_tick`
    /// whenever it applies (falling back to `tick`, with the engine's
    /// idle/drain pattern) is bitwise indistinguishable from pure
    /// `tick`ing — including `reboots`, which depends on the `was_on`
    /// bookkeeping `off_tick` must keep in sync.
    #[test]
    fn off_tick_walk_is_bitwise_equal_to_naive_ticks() {
        let h = Harvester::markov(HarvesterKind::Rf, 30.0, 0.9, 0.3, 1000.0, 5);
        let mut fast = EnergyManager::new(Capacitor::new(0.005, 3.3, 2.8, 1.9), h, 0.5, 0.05);
        let mut slow = fast.clone();
        for i in 0..500_000u64 {
            if !fast.off_tick(5.0) {
                fast.tick(5.0);
                if fast.capacitor.mcu_on() {
                    fast.capacitor.draw(0.08); // engine-style on-drain
                }
            }
            slow.tick(5.0);
            if slow.capacitor.mcu_on() {
                slow.capacitor.draw(0.08);
            }
            if i % 25_000 == 0 {
                assert_eq!(format!("{fast:?}"), format!("{slow:?}"), "diverged at {i}");
            }
        }
        assert_eq!(format!("{fast:?}"), format!("{slow:?}"));
        assert_eq!(fast.reboots, slow.reboots);
        assert!(fast.reboots > 1, "walk never cycled power: reboots={}", fast.reboots);
    }

    /// `fast_forward_dark` + the capacitor bulk drain must be bitwise
    /// equal to naive `tick` + `idle_drain` pairs across dark windows with
    /// the MCU **on** — including the reboot observation when the first
    /// tick after a precharge boot is a dark one.
    #[test]
    fn dark_bulk_with_mcu_on_matches_naive_ticks_bitwise() {
        let mk = || {
            let mut cap = Capacitor::standard();
            cap.precharge(); // boots before any tick: was_on starts stale
            // Piezo starts in a dark window, so the very first tick — the
            // one that must observe the precharge boot — goes through the
            // bulk path.
            EnergyManager::new(cap, Harvester::piezo(9), 0.5, 0.05)
        };
        let mut bulk = mk();
        let mut naive = mk();
        let (dt, power) = (5.0, 0.3);
        let drain = power * dt * 1e-3;
        let mut bulked = 0u64;
        for i in 0..20_000u64 {
            let n = bulk
                .harvester
                .off_ticks_hint(dt)
                .min(bulk.capacitor.idle_ticks_above(bulk.capacitor.floor_mj() + 2.0 * drain, drain))
                .min(500); // keep interleaving with boundary ticks frequent
            if n > 0 && bulk.capacitor.mcu_on() {
                bulk.fast_forward_dark(n, dt);
                bulk.capacitor.fast_forward_idle_drain(power, dt, n);
                for _ in 0..n {
                    naive.tick(dt);
                    naive.capacitor.idle_drain(power, dt);
                }
                bulked += n;
            } else {
                bulk.tick(dt);
                bulk.capacitor.idle_drain(power, dt);
                naive.tick(dt);
                naive.capacitor.idle_drain(power, dt);
            }
            if i % 512 == 0 {
                assert_eq!(format!("{bulk:?}"), format!("{naive:?}"), "diverged at {i}");
            }
        }
        assert_eq!(format!("{bulk:?}"), format!("{naive:?}"));
        assert_eq!(bulk.reboots, naive.reboots);
        assert!(bulk.reboots >= 1, "precharge boot must be observed");
        assert!(bulked > 10_000, "bulk path never engaged meaningfully: {bulked}");
    }

    #[test]
    fn reboot_counting_with_bursty_source() {
        let h = Harvester::markov(HarvesterKind::Rf, 30.0, 0.9, 0.4, 1000.0, 5);
        let mut m = EnergyManager::new(
            Capacitor::new(0.005, 3.3, 2.8, 1.9),
            h,
            0.5,
            0.05,
        );
        // Simulate long enough to see multiple boot cycles; drain faster
        // than the average harvest (30 mW * 0.4 duty = 12 mW) while on.
        for _ in 0..500_000 {
            m.tick(10.0);
            if m.capacitor.mcu_on() {
                m.capacitor.draw(0.2); // 20 mW equivalent drain
            }
        }
        assert!(m.reboots > 1, "reboots={}", m.reboots);
    }
}
