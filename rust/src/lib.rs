//! # Zygarde — time-sensitive on-device deep inference on intermittent power
//!
//! A full reproduction of *Zygarde: Time-Sensitive On-Device Deep Inference
//! and Adaptation on Intermittently-Powered Systems* (Islam & Nirjon, IMWUT
//! 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3: the coordinator. It owns the imprecise-computing
//! real-time scheduler (the paper's contribution), the intermittent-MCU
//! simulation substrate (harvesters, capacitor, fragment-atomic execution,
//! remanence clocks), the per-layer k-means classifiers with online
//! adaptation, and a PJRT runtime that executes the AOT-compiled per-unit
//! HLO artifacts produced by `python/compile/aot.py`. Python never runs on
//! the request path.
//!
//! Module map (see DESIGN.md for the per-experiment index):
//!
//! * [`util`] — hand-rolled substrates: JSON, RNG, CLI, stats, ZYGT tensor
//!   archive, property-test + bench harnesses (the image is offline; no
//!   serde/clap/criterion/proptest).
//! * [`runtime`] — XLA PJRT client; loads `artifacts/<ds>/unit<i>.hlo.txt`.
//! * [`dnn`] — agile-DNN metadata, native forward (validated against PJRT),
//!   k-means classifiers, utility test, centroid adaptation, unit traces.
//! * [`energy`] — energy events, η-factor, harvester models, capacitor,
//!   cost model, energy manager.
//! * [`nvm`] — nonvolatile progress: FRAM-like commit/restore cost model
//!   and the checkpoint-commit policies (every-fragment, unit-boundary,
//!   JIT voltage-triggered); the engine charges commit/restore energy and
//!   rolls volatile progress back to the last commit on power failure.
//! * [`clock`] — RTC and CHRT remanence-clock models.
//! * [`coordinator`] — tasks/jobs/units/fragments, job queue, priority
//!   functions ζ and ζ_I, Zygarde/EDF/EDF-M/RR schedulers, schedulability.
//! * [`sim`] — discrete-event intermittently-powered MCU simulator, plus
//!   the deterministic parallel scenario-sweep engine ([`sim::sweep`]).
//! * [`telemetry`] — three observability layers, all provably byte-neutral
//!   to reports: per-cell engine event traces (typed events, sinks, Chrome
//!   `trace_event` / JSONL exporters; `zygarde trace`, `zygarde sweep
//!   --trace-dir`), the campaign metrics registry
//!   ([`telemetry::registry`]: deterministic counters/log2-histograms with
//!   order-independent merge, surfaced as `zygarde profile --by AXIS`),
//!   and the cross-layer serve timeline ([`telemetry::timeline`]: lease
//!   lifecycle spans, journal recovery, and simnet fault events on one
//!   Chrome trace via `zygarde serve|simtest --trace-out F`).
//! * [`classifiers`] — KNN / k-means / SVM / random-forest baselines
//!   (Table 7).
//! * [`exp`] — one driver per paper table/figure (the scheduler,
//!   capacitor, and clock comparisons run on the sweep engine).
//!
//! # Deterministic simulation & sweeps
//!
//! The evaluation grid — harvester profiles × capacitor sizes ×
//! schedulers × exit policies × task mixes × seeds — is declared as a
//! [`sim::sweep::ScenarioMatrix`] and executed by a multi-threaded runner
//! whose output is **bitwise identical at any thread count**: every
//! scenario derives its RNG streams from `(matrix_seed, scenario_index)`
//! and shares no mutable state. Failure injection (brownout bursts,
//! post-reboot CHRT clock skew) is part of the scenario spec, so a
//! failing seed replays exactly and becomes a regression test.
//!
//! ```no_run
//! use zygarde::coordinator::sched::SchedulerKind;
//! use zygarde::sim::sweep::{run_matrix, FaultPlan, HarvesterSpec, ScenarioMatrix, TaskMix};
//!
//! let matrix = ScenarioMatrix::new("quick", 7)
//!     .mixes(vec![TaskMix::synthetic("demo", 2, 3, 42)])
//!     .harvesters(vec![
//!         HarvesterSpec::System(6), // Table 4: RF, η = 0.51
//!         HarvesterSpec::Persistent { power_mw: 600.0 },
//!     ])
//!     .capacitors_mf(vec![5.0, 50.0])
//!     .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
//!     .faults(vec![
//!         FaultPlan::none(),
//!         FaultPlan::none().with_brownouts(2_000.0, 400.0, 0.0),
//!     ])
//!     .reps(4);
//! let report = run_matrix(&matrix, 8);
//! report.print();
//! println!("{}", report.json_string());
//! ```
//!
//! To replay one cell from a report, re-expand the same matrix and run
//! its scenario index alone — `sim::sweep::run_scenario` is a pure
//! function of the scenario, so the isolated replay matches the sweep
//! cell byte-for-byte (`rust/tests/sweep_determinism.rs` enforces this).

pub mod classifiers;
pub mod clock;
pub mod coordinator;
pub mod dnn;
pub mod energy;
pub mod exp;
pub mod nvm;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod util;

/// Root of the artifact tree produced by `make artifacts`.
pub fn artifacts_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ZYGARDE_ARTIFACTS") {
        return p.into();
    }
    // Works from the repo root (cargo run) and from target/ binaries.
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join(".stamp").exists() || p.join("mnist/meta.json").exists() {
            return p;
        }
    }
    std::path::PathBuf::from("artifacts")
}
