//! # Zygarde — time-sensitive on-device deep inference on intermittent power
//!
//! A full reproduction of *Zygarde: Time-Sensitive On-Device Deep Inference
//! and Adaptation on Intermittently-Powered Systems* (Islam & Nirjon, IMWUT
//! 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3: the coordinator. It owns the imprecise-computing
//! real-time scheduler (the paper's contribution), the intermittent-MCU
//! simulation substrate (harvesters, capacitor, fragment-atomic execution,
//! remanence clocks), the per-layer k-means classifiers with online
//! adaptation, and a PJRT runtime that executes the AOT-compiled per-unit
//! HLO artifacts produced by `python/compile/aot.py`. Python never runs on
//! the request path.
//!
//! Module map (see DESIGN.md for the per-experiment index):
//!
//! * [`util`] — hand-rolled substrates: JSON, RNG, CLI, stats, ZYGT tensor
//!   archive, property-test + bench harnesses (the image is offline; no
//!   serde/clap/criterion/proptest).
//! * [`runtime`] — XLA PJRT client; loads `artifacts/<ds>/unit<i>.hlo.txt`.
//! * [`dnn`] — agile-DNN metadata, native forward (validated against PJRT),
//!   k-means classifiers, utility test, centroid adaptation, unit traces.
//! * [`energy`] — energy events, η-factor, harvester models, capacitor,
//!   cost model, energy manager.
//! * [`clock`] — RTC and CHRT remanence-clock models.
//! * [`coordinator`] — tasks/jobs/units/fragments, job queue, priority
//!   functions ζ and ζ_I, Zygarde/EDF/EDF-M/RR schedulers, schedulability.
//! * [`sim`] — discrete-event intermittently-powered MCU simulator.
//! * [`classifiers`] — KNN / k-means / SVM / random-forest baselines
//!   (Table 7).
//! * [`exp`] — one driver per paper table/figure.

pub mod classifiers;
pub mod clock;
pub mod coordinator;
pub mod dnn;
pub mod energy;
pub mod exp;
pub mod runtime;
pub mod sim;
pub mod util;

/// Root of the artifact tree produced by `make artifacts`.
pub fn artifacts_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ZYGARDE_ARTIFACTS") {
        return p.into();
    }
    // Works from the repo root (cargo run) and from target/ binaries.
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join(".stamp").exists() || p.join("mnist/meta.json").exists() {
            return p;
        }
    }
    std::path::PathBuf::from("artifacts")
}
