//! PJRT runtime facade.
//!
//! The real implementation ([`pjrt`]) loads the AOT-compiled per-unit HLO
//! artifacts and executes them on the XLA CPU client; it is the only code
//! in the crate that touches the `xla` crate and is therefore gated behind
//! the `pjrt` cargo feature (the build image must ship the vendored `xla`
//! and `anyhow` crates — see Cargo.toml). With the feature off, a stub
//! [`Runtime`] with the same surface reports itself unavailable, so the
//! simulation, scheduling, and sweep stacks build with zero external
//! dependencies.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Runtime, UnitExe};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Runtime, RuntimeUnavailable};
