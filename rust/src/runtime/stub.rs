//! Stub runtime compiled when the `pjrt` feature is off.
//!
//! Mirrors the public surface of [`super::pjrt`] so callers compile
//! unchanged; every operation fails with [`RuntimeUnavailable`]. The
//! native forward path (`dnn::forward`, used by the simulator and the
//! trace precomputation) does not go through here and keeps working.

use std::path::Path;

use crate::dnn::meta::NetMeta;

/// Error returned by every stub operation.
#[derive(Clone, Copy, Debug)]
pub struct RuntimeUnavailable;

impl std::fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (rebuild with `--features pjrt` on an image that vendors the \
             `xla` crate)"
        )
    }
}

impl std::error::Error for RuntimeUnavailable {}

/// Placeholder with the same API as the PJRT-backed runtime.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Self, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load_network(
        &mut self,
        _dir: &Path,
        _meta: &NetMeta,
    ) -> Result<(), RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn load_unit(
        &mut self,
        _dir: &Path,
        _meta: &NetMeta,
        _li: usize,
    ) -> Result<(), RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn has_unit(&self, _net: &str, _li: usize) -> bool {
        false
    }

    pub fn execute_unit(
        &self,
        _net: &str,
        _li: usize,
        _act_in: &[f32],
        _centroids: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>), RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn loaded_units(&self) -> usize {
        0
    }
}
