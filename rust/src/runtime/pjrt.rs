//! PJRT runtime: loads the AOT-compiled per-unit HLO artifacts and executes
//! them on the XLA CPU client. This is the only place the `xla` crate is
//! touched; everything above deals in plain `Vec<f32>` activations.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): the
//! image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids), while the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md §2.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::dnn::meta::NetMeta;

/// One compiled per-unit executable: `(act_in, centroids) -> (act_out, dists)`.
pub struct UnitExe {
    exe: xla::PjRtLoadedExecutable,
    pub act_in_len: usize,
    pub act_in_dims: Vec<i64>,
    pub k: usize,
    pub n_features: usize,
}

/// A PJRT client plus the executable cache for one or more networks.
pub struct Runtime {
    client: xla::PjRtClient,
    units: HashMap<(String, usize), UnitExe>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, units: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile every unit of a network from `dir` (e.g. `artifacts/mnist`).
    pub fn load_network(&mut self, dir: &Path, meta: &NetMeta) -> Result<()> {
        for li in 0..meta.n_layers {
            self.load_unit(dir, meta, li)?;
        }
        Ok(())
    }

    pub fn load_unit(&mut self, dir: &Path, meta: &NetMeta, li: usize) -> Result<()> {
        let key = (meta.name.clone(), li);
        if self.units.contains_key(&key) {
            return Ok(());
        }
        let path: PathBuf = dir.join(format!("unit{li}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let in_dims = meta.unit_input_shape(li);
        self.units.insert(
            key,
            UnitExe {
                exe,
                act_in_len: in_dims.iter().product::<i64>() as usize,
                act_in_dims: in_dims,
                k: meta.layers[li].k,
                n_features: meta.layers[li].n_features,
            },
        );
        Ok(())
    }

    pub fn has_unit(&self, net: &str, li: usize) -> bool {
        self.units.contains_key(&(net.to_string(), li))
    }

    /// Execute one unit: feed the previous activation (flattened) and the
    /// *current* centroids (they evolve at runtime via adaptation), get the
    /// next activation and the k L1 distances.
    pub fn execute_unit(
        &self,
        net: &str,
        li: usize,
        act_in: &[f32],
        centroids: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let unit = self
            .units
            .get(&(net.to_string(), li))
            .with_context(|| format!("unit {net}/{li} not loaded"))?;
        anyhow::ensure!(
            act_in.len() == unit.act_in_len,
            "unit {net}/{li}: activation len {} != expected {}",
            act_in.len(),
            unit.act_in_len
        );
        anyhow::ensure!(
            centroids.len() == unit.k * unit.n_features,
            "unit {net}/{li}: centroid len {} != {}x{}",
            centroids.len(),
            unit.k,
            unit.n_features
        );
        let x = xla::Literal::vec1(act_in).reshape(&unit.act_in_dims)?;
        let c = xla::Literal::vec1(centroids)
            .reshape(&[unit.k as i64, unit.n_features as i64])?;
        let result = unit.exe.execute::<xla::Literal>(&[x, c])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: root is (act_out, dists).
        let (act_out, dists) = result.to_tuple2()?;
        Ok((act_out.to_vec::<f32>()?, dists.to_vec::<f32>()?))
    }

    pub fn loaded_units(&self) -> usize {
        self.units.len()
    }
}

#[cfg(test)]
mod tests {
    // PJRT tests live in rust/tests/runtime_vs_native.rs (integration):
    // they need built artifacts and the shared CPU client.
}
