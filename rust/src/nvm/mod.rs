//! Nonvolatile progress: what survives a power failure, and at what cost.
//!
//! The seed engine idealized NVM: every completed fragment persisted for
//! free, so a power failure lost only the in-flight fragment. Real
//! intermittent systems pay for persistence — SONIC-style idempotent
//! re-execution vs. checkpointing is the central design trade-off of the
//! field — and Zygarde's §8 overhead numbers only make sense against an
//! explicit commit-cost model. This module makes that model a first-class,
//! swappable subsystem:
//!
//! * [`NvmModel`] — FRAM-like per-byte write/read energy and bandwidth.
//!   Commit/restore costs derive from the per-unit state sizes declared on
//!   `TaskSpec::unit_state_bytes` (the activation buffer a checkpoint at a
//!   fragment boundary of that unit must persist).
//! * [`CommitPolicy`] — *when* volatile progress is made durable:
//!   - [`CommitPolicy::EveryFragment`] commits at every fragment boundary
//!     (the seed engine's semantics, now with a real commit cost);
//!   - [`CommitPolicy::UnitBoundary`] commits only when a unit completes —
//!     cheaper steady-state, but a brownout rolls the job back to the last
//!     unit boundary and the mid-unit fragments re-execute;
//!   - [`CommitPolicy::JitVoltage`] keeps everything volatile and commits
//!     a single system snapshot only when the capacitor voltage sags to
//!     within a margin of brown-out (the Hibernus/QuickRecall JIT-
//!     checkpoint idiom, exposed by `EnergyManager::jit_voltage_trigger`).
//! * [`NvmSpec`] — the declarative (model, policy) pair a
//!   `sim::sweep::ScenarioMatrix` holds as its NVM axis; [`Nvm`] is the
//!   per-engine runtime state built from it.
//!
//! The default everywhere is [`NvmSpec::ideal`] — a zero-cost
//! `EveryFragment` — which reproduces the seed engine's dynamics exactly
//! (no extra energy draws, no extra time, no RNG disturbance); the golden
//! sweep snapshot is pinned to it (`rust/tests/sweep_golden.rs`).

use crate::energy::capacitor::Capacitor;

/// FRAM-like nonvolatile-memory cost model. Costs scale linearly in the
/// committed/restored bytes; `base_commit_bytes` is the fixed metadata a
/// commit record always carries (registers, stack, queue bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NvmModel {
    /// Write energy per byte (nJ/B).
    pub write_nj_per_byte: f64,
    /// Read (restore) energy per byte (nJ/B).
    pub read_nj_per_byte: f64,
    /// Write bandwidth (bytes per ms); `f64::INFINITY` = instantaneous.
    pub write_bytes_per_ms: f64,
    /// Read bandwidth (bytes per ms); `f64::INFINITY` = instantaneous.
    pub read_bytes_per_ms: f64,
    /// Fixed per-commit metadata bytes on top of the task state.
    pub base_commit_bytes: usize,
}

impl NvmModel {
    /// Free, instantaneous persistence — the seed engine's idealization.
    pub fn ideal() -> Self {
        NvmModel {
            write_nj_per_byte: 0.0,
            read_nj_per_byte: 0.0,
            write_bytes_per_ms: f64::INFINITY,
            read_bytes_per_ms: f64::INFINITY,
            base_commit_bytes: 0,
        }
    }

    /// MSP430 FR59xx-class FRAM: a ~2 KB unit checkpoint costs ~6.5 µJ
    /// and ~0.27 ms — ~1.3 % of a 0.5 mJ / 5 ms fragment, in line with the
    /// low-single-digit checkpoint overheads the intermittent-computing
    /// literature reports.
    pub fn fram() -> Self {
        NvmModel {
            write_nj_per_byte: 3.0,
            read_nj_per_byte: 1.2,
            write_bytes_per_ms: 8_000.0,
            read_bytes_per_ms: 16_000.0,
            base_commit_bytes: 128,
        }
    }

    /// Energy (mJ) and latency (ms) to commit `bytes`.
    pub fn commit_cost(&self, bytes: usize) -> (f64, f64) {
        let e_mj = bytes as f64 * self.write_nj_per_byte * 1e-6;
        let t_ms = if self.write_bytes_per_ms.is_finite() && self.write_bytes_per_ms > 0.0 {
            bytes as f64 / self.write_bytes_per_ms
        } else {
            0.0
        };
        (e_mj, t_ms)
    }

    /// Energy (mJ) and latency (ms) to restore `bytes` after a reboot.
    pub fn restore_cost(&self, bytes: usize) -> (f64, f64) {
        let e_mj = bytes as f64 * self.read_nj_per_byte * 1e-6;
        let t_ms = if self.read_bytes_per_ms.is_finite() && self.read_bytes_per_ms > 0.0 {
            bytes as f64 / self.read_bytes_per_ms
        } else {
            0.0
        };
        (e_mj, t_ms)
    }

    /// True when every transaction is free and instantaneous.
    pub fn is_free(&self) -> bool {
        self.write_nj_per_byte == 0.0
            && self.read_nj_per_byte == 0.0
            && !self.write_bytes_per_ms.is_finite()
            && !self.read_bytes_per_ms.is_finite()
    }
}

/// When volatile progress is made durable.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CommitPolicy {
    /// Commit after every successful fragment (seed-engine semantics).
    EveryFragment,
    /// Commit only when a unit completes; mid-unit progress is volatile.
    UnitBoundary,
    /// Commit a whole-system snapshot only when the capacitor voltage
    /// falls to within `margin_v` of the brown-out threshold.
    JitVoltage {
        /// Volts above `v_off` at which the checkpoint fires.
        margin_v: f64,
    },
}

impl CommitPolicy {
    /// The JIT policy with the default 0.1 V trigger margin.
    pub fn jit() -> Self {
        CommitPolicy::JitVoltage { margin_v: 0.1 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CommitPolicy::EveryFragment => "frag",
            CommitPolicy::UnitBoundary => "unit",
            CommitPolicy::JitVoltage { .. } => "jit",
        }
    }
}

/// Which cost model a scenario uses (a plain value a matrix can hold).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NvmModelKind {
    Ideal,
    Fram,
}

impl NvmModelKind {
    pub fn build(self) -> NvmModel {
        match self {
            NvmModelKind::Ideal => NvmModel::ideal(),
            NvmModelKind::Fram => NvmModel::fram(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NvmModelKind::Ideal => "ideal",
            NvmModelKind::Fram => "fram",
        }
    }
}

/// Declarative (model, policy) pair — the `sim::sweep` NVM scenario axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NvmSpec {
    pub model: NvmModelKind,
    pub policy: CommitPolicy,
}

impl NvmSpec {
    /// Zero-cost `EveryFragment`: bitwise-reproduces the seed engine.
    pub fn ideal() -> Self {
        NvmSpec { model: NvmModelKind::Ideal, policy: CommitPolicy::EveryFragment }
    }

    pub fn fram_every_fragment() -> Self {
        NvmSpec { model: NvmModelKind::Fram, policy: CommitPolicy::EveryFragment }
    }

    pub fn fram_unit_boundary() -> Self {
        NvmSpec { model: NvmModelKind::Fram, policy: CommitPolicy::UnitBoundary }
    }

    pub fn fram_jit() -> Self {
        NvmSpec { model: NvmModelKind::Fram, policy: CommitPolicy::jit() }
    }

    /// Stable cell-label segment, e.g. `ideal+frag`, `fram+jit`.
    pub fn label(&self) -> String {
        format!("{}+{}", self.model.name(), self.policy.name())
    }

    /// Parse a CLI policy name (`--nvm` flags): `ideal`, `fram-frag`,
    /// `fram-unit`, `fram-jit`. `+` separators (the [`NvmSpec::label`]
    /// form) are accepted too.
    pub fn parse(s: &str) -> Result<NvmSpec, String> {
        match s.trim().replace('+', "-").as_str() {
            "ideal" | "ideal-frag" => Ok(NvmSpec::ideal()),
            "fram" | "fram-frag" => Ok(NvmSpec::fram_every_fragment()),
            "fram-unit" => Ok(NvmSpec::fram_unit_boundary()),
            "fram-jit" => Ok(NvmSpec::fram_jit()),
            other => Err(format!(
                "unknown NVM policy `{other}` (known: ideal, fram-frag, fram-unit, fram-jit)"
            )),
        }
    }

    /// Parse a comma-separated policy list, e.g. `ideal,fram-jit`.
    pub fn parse_list(s: &str) -> Result<Vec<NvmSpec>, String> {
        s.split(',').filter(|p| !p.trim().is_empty()).map(NvmSpec::parse).collect()
    }
}

impl Default for NvmSpec {
    fn default() -> Self {
        Self::ideal()
    }
}

/// Per-engine NVM runtime state, built from an [`NvmSpec`] against the
/// scenario's capacitor (the JIT threshold is an absolute voltage).
#[derive(Clone, Debug)]
pub struct Nvm {
    pub model: NvmModel,
    pub policy: CommitPolicy,
    /// Absolute JIT trigger voltage (`v_off + margin_v`).
    pub jit_threshold_v: f64,
    /// Voltage at which a fired trigger re-arms (hysteresis above the
    /// threshold so a sagging capacitor checkpoints once, not per tick).
    pub jit_rearm_v: f64,
    /// The trigger fires only while armed; it disarms on commit and
    /// re-arms once the voltage recovers past `jit_rearm_v` (or on boot).
    pub jit_armed: bool,
    /// Set when a power failure rolled volatile progress back; the engine
    /// pays the restore cost before the next execution after reboot.
    pub pending_restore: bool,
}

impl Nvm {
    pub fn build(spec: NvmSpec, cap: &Capacitor) -> Self {
        let margin = match spec.policy {
            CommitPolicy::JitVoltage { margin_v } => margin_v,
            _ => 0.0,
        };
        let threshold = cap.v_off + margin;
        Nvm {
            model: spec.model.build(),
            policy: spec.policy,
            jit_threshold_v: threshold,
            jit_rearm_v: threshold + 0.5 * margin,
            jit_armed: true,
            pending_restore: false,
        }
    }

    /// The default runtime state: zero-cost `EveryFragment`.
    pub fn ideal(cap: &Capacitor) -> Self {
        Self::build(NvmSpec::ideal(), cap)
    }

    /// True when the policy consults the JIT voltage trigger. The engine's
    /// event-driven idle loops use this (with `jit_threshold_v` /
    /// `jit_rearm_v` / `jit_armed`) to budget how far a dark window can be
    /// fast-forwarded before the trigger could possibly fire: an unarmed
    /// trigger below `jit_rearm_v` stays unarmed while the voltage is
    /// non-increasing, an armed one with no dirty jobs commits nothing
    /// (`jit_commit_all` is a pure no-op then), and otherwise the
    /// `EnergyManager::ticks_above_voltage` predictor bounds the crossing.
    pub fn is_jit(&self) -> bool {
        matches!(self.policy, CommitPolicy::JitVoltage { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_model_is_free() {
        let m = NvmModel::ideal();
        assert!(m.is_free());
        assert_eq!(m.commit_cost(4096), (0.0, 0.0));
        assert_eq!(m.restore_cost(4096), (0.0, 0.0));
    }

    #[test]
    fn fram_costs_scale_linearly_in_bytes() {
        let m = NvmModel::fram();
        assert!(!m.is_free());
        let (e1, t1) = m.commit_cost(1000);
        let (e2, t2) = m.commit_cost(2000);
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
        assert!((t2 - 2.0 * t1).abs() < 1e-12);
        // 1000 B at 3 nJ/B = 3 µJ = 0.003 mJ.
        assert!((e1 - 0.003).abs() < 1e-12);
        // Reads are cheaper and faster than writes.
        let (er, tr) = m.restore_cost(1000);
        assert!(er < e1 && tr < t1);
    }

    #[test]
    fn commit_cost_is_small_relative_to_a_fragment() {
        // A 2 KB unit checkpoint must stay in the low single-digit
        // percents of a 0.5 mJ / 5 ms fragment, or the overhead numbers
        // stop being paper-plausible.
        let m = NvmModel::fram();
        let (e, t) = m.commit_cost(m.base_commit_bytes + 2048);
        assert!(e > 0.0 && e < 0.5 * 0.05, "commit energy {e} mJ too large");
        assert!(t > 0.0 && t < 5.0 * 0.10, "commit latency {t} ms too large");
    }

    #[test]
    fn spec_labels_are_stable() {
        assert_eq!(NvmSpec::ideal().label(), "ideal+frag");
        assert_eq!(NvmSpec::fram_every_fragment().label(), "fram+frag");
        assert_eq!(NvmSpec::fram_unit_boundary().label(), "fram+unit");
        assert_eq!(NvmSpec::fram_jit().label(), "fram+jit");
        assert_eq!(NvmSpec::default(), NvmSpec::ideal());
    }

    #[test]
    fn cli_names_parse_to_specs() {
        assert_eq!(NvmSpec::parse("ideal").unwrap(), NvmSpec::ideal());
        assert_eq!(NvmSpec::parse("fram-frag").unwrap(), NvmSpec::fram_every_fragment());
        assert_eq!(NvmSpec::parse("fram+unit").unwrap(), NvmSpec::fram_unit_boundary());
        assert_eq!(NvmSpec::parse(" fram-jit ").unwrap(), NvmSpec::fram_jit());
        assert!(NvmSpec::parse("flash").is_err());
        assert_eq!(
            NvmSpec::parse_list("ideal,fram-jit").unwrap(),
            vec![NvmSpec::ideal(), NvmSpec::fram_jit()]
        );
        assert!(NvmSpec::parse_list("ideal,bogus").is_err());
    }

    #[test]
    fn jit_threshold_sits_between_off_and_on() {
        let cap = Capacitor::standard(); // v_on 2.8, v_off 1.9
        let nvm = Nvm::build(NvmSpec::fram_jit(), &cap);
        assert!(nvm.jit_threshold_v > cap.v_off);
        assert!(nvm.jit_threshold_v < cap.v_on);
        assert!(nvm.jit_rearm_v > nvm.jit_threshold_v);
        assert!(nvm.jit_armed);
        assert!(!nvm.pending_restore);
    }
}
