#!/usr/bin/env python3
"""Seeded-failure soak driver for the deterministic simnet.

Runs ``zygarde simtest`` campaigns — whole serve sessions over the
seeded, single-threaded simulated network (virtual clock, no sockets, no
worker processes) — in two phases:

1. **Corpus replay.** Every ``*.seed`` file under the corpus directory
   (default ``rust/tests/seeds/serve``) is one line of whitespace-
   separated ``key=value`` tokens describing a campaign (``seed`` is
   required; ``workers``, ``reps``, ``duration-ms``, ``faults``,
   ``lease``, ``lease-timeout-ms``, ``spill-cells`` override the
   ``simtest`` defaults; the ``faults`` value may itself contain ``=``
   and ``,``). Committed seeds are campaigns that once failed or that
   pin tricky fault mixes — they are replayed forever.

2. **Exploration.** ``--explore N`` fresh seeds derived from
   ``--explore-base`` (pass e.g. the CI run number so every run probes
   new schedules) with seed-derived fault plans and a rotating worker
   count. Campaigns are deterministic in the seed, so any failure is
   perfectly reproducible: the script prints the exact one-line seed
   file to commit, which turns the find into a permanent regression.

``zygarde simtest`` itself verifies the invariant — the streamed report
must be byte-identical to the single-process sweep — and exits nonzero
(printing reproduce/commit instructions) on any divergence, wedge, or
virtual-horizon overrun.

``--self-test`` checks the seed-line parser and argument translation
against built-in good and bad lines (no binary needed) and exits nonzero
on any wrong verdict.
"""

import argparse
import glob
import os
import subprocess
import sys

# Keep in sync with `zygarde simtest` flag defaults and the parser in
# rust/tests/sweep_simnet.rs — the three views of a seed line must mean
# the same campaign.
DEFAULTS = {
    "workers": "32",
    "reps": "2",
    "duration-ms": "6000",
    "faults": "",
    "lease": "0",
    "lease-timeout-ms": "300",
    "spill-cells": "32",
}
KNOWN_KEYS = {"seed"} | set(DEFAULTS)


def parse_seed_line(text, origin):
    """Parse one seed line into a full key->value dict (defaults filled)."""
    entry = dict(DEFAULTS)
    saw_seed = False
    for tok in text.split():
        if "=" not in tok:
            raise ValueError(f"{origin}: `{tok}` is not key=value")
        key, val = tok.split("=", 1)
        if key not in KNOWN_KEYS:
            raise ValueError(f"{origin}: unknown seed key `{key}`")
        if key == "seed":
            int(val)  # must be an integer
            saw_seed = True
        entry[key] = val
    if not saw_seed:
        raise ValueError(f"{origin}: no seed= token")
    return entry


def entry_args(entry):
    """Translate a parsed entry into the `zygarde simtest` argv tail."""
    args = ["simtest", "--matrix", "synthetic", "--seed", entry["seed"]]
    for key in ("workers", "reps", "duration-ms", "lease",
                "lease-timeout-ms", "spill-cells"):
        args += [f"--{key}", entry[key]]
    if entry["faults"]:
        args += ["--faults", entry["faults"]]
    return args


def run_campaign(binary, entry, label):
    argv = [binary] + entry_args(entry)
    print(f"--- {label}: {' '.join(argv[1:])}", flush=True)
    proc = subprocess.run(argv)
    return proc.returncode == 0


def replay_corpus(binary, corpus):
    paths = sorted(glob.glob(os.path.join(corpus, "*.seed")))
    if not paths:
        print(f"::error::seed corpus {corpus} is empty")
        return False
    ok = True
    for path in paths:
        with open(path) as f:
            entry = parse_seed_line(f.read(), path)
        if not run_campaign(binary, entry, f"corpus {os.path.basename(path)}"):
            print(f"::error::committed seed {entry['seed']} ({path}) regressed")
            ok = False
    print(f"corpus: {len(paths)} committed seed(s) replayed")
    return ok


def explore(binary, binary_count, base):
    """Run `binary_count` fresh seeds; report the commit line on failure."""
    worker_rotation = (8, 24, 64, 200)
    for i in range(binary_count):
        # Spread seeds deterministically from the base so consecutive CI
        # runs (base = run number) never repeat a schedule.
        seed = (base * 1_000_003 + i * 7_919) & 0xFFFF_FFFF
        entry = dict(DEFAULTS)
        entry.update({
            "seed": str(seed),
            "workers": str(worker_rotation[i % len(worker_rotation)]),
            "reps": "1",
            "duration-ms": "800",
        })
        if not run_campaign(binary, entry, f"explore {i + 1}/{binary_count}"):
            line = (f"seed={seed} workers={entry['workers']} reps=1 "
                    f"duration-ms=800")
            print(f"::error::simnet exploration found a failing seed: {seed}")
            print("commit it as a permanent regression:")
            print(f'  echo "{line}" > rust/tests/seeds/serve/seed_{seed}.seed')
            return False
    print(f"exploration: {binary_count} fresh seed(s) passed")
    return True


def self_test():
    failures = []

    def check(name, cond):
        if not cond:
            failures.append(name)

    e = parse_seed_line(
        "seed=11 workers=200 reps=2 duration-ms=1200 "
        "faults=latency=1..20,drop=0.02,crash=3", "<good>")
    check("seed kept", e["seed"] == "11")
    check("workers kept", e["workers"] == "200")
    check("faults keeps = and ,", e["faults"] == "latency=1..20,drop=0.02,crash=3")
    check("defaults filled", e["lease-timeout-ms"] == "300" and e["lease"] == "0")

    e = parse_seed_line("seed=7", "<minimal>")
    check("minimal gets all defaults", e["workers"] == "32" and e["faults"] == "")

    argv = entry_args(e)
    check("argv names the matrix", argv[:3] == ["simtest", "--matrix", "synthetic"])
    check("argv carries the seed", "--seed" in argv and "7" in argv)
    check("empty faults omitted", "--faults" not in argv)
    argv = entry_args(parse_seed_line("seed=1 faults=none", "<none>"))
    check("explicit faults passed", argv[-2:] == ["--faults", "none"])

    # Dispatcher-crash grammar (`dcrash=N`) rides inside the faults value
    # verbatim — the binary's FaultSpec parser owns the grammar, so the
    # soak driver must pass it through untouched.
    e = parse_seed_line(
        "seed=13 workers=200 reps=2 duration-ms=1200 "
        "faults=latency=1..20,drop=0.02,dcrash=2 spill-cells=8", "<dcrash>")
    check("dcrash passes through", e["faults"] == "latency=1..20,drop=0.02,dcrash=2")
    check("spill-cells kept", e["spill-cells"] == "8")
    argv = entry_args(e)
    check("dcrash reaches argv",
          argv[-1] == "latency=1..20,drop=0.02,dcrash=2")

    for bad in ("workers=3", "seed=x", "seed=1 warp=9", "seed=1 bare"):
        try:
            parse_seed_line(bad, "<bad>")
            failures.append(f"accepted bad line `{bad}`")
        except ValueError:
            pass

    if failures:
        for f in failures:
            print(f"self-test FAILED: {f}")
        return False
    print("simnet_soak self-test passed")
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--bin", default="./target/release/zygarde",
                    help="zygarde binary to drive")
    ap.add_argument("--corpus", default="rust/tests/seeds/serve",
                    help="directory of committed *.seed files")
    ap.add_argument("--explore", type=int, default=0, metavar="N",
                    help="additionally run N fresh exploration seeds")
    ap.add_argument("--explore-base", type=int, default=1,
                    help="base the exploration seeds derive from "
                         "(pass the CI run number for fresh schedules)")
    ap.add_argument("--self-test", action="store_true",
                    help="validate the parser/translator and exit")
    args = ap.parse_args()

    if args.self_test:
        sys.exit(0 if self_test() else 1)

    ok = replay_corpus(args.bin, args.corpus)
    if ok and args.explore > 0:
        ok = explore(args.bin, args.explore, args.explore_base)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
