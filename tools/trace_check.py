#!/usr/bin/env python3
"""Structural validator for the Chrome ``trace_event`` JSON that
``zygarde trace --format chrome`` and ``zygarde sweep --trace-dir``
emit.

Checks, per file:

* the document is an object with a ``traceEvents`` list;
* every event has a string ``name`` and a ``ph`` in {B, E, X, i, M};
* every non-metadata event has a numeric ``ts`` >= 0;
* ``X`` (complete/duration) events carry a numeric ``dur`` >= 0;
* ``i`` (instant) events carry a scope ``s`` in {g, p, t};
* per ``(pid, tid)`` track, ``B``/``E`` events balance like brackets —
  every ``E`` closes the most recent open ``B`` of the same name, and
  nothing is left open at end of file (the exporter never nests
  fragments, but the check allows well-formed nesting);
* per ``(pid, tid)`` track, ``ts`` is monotone non-decreasing over
  B/E/i events (``X`` events are sorted by their *start*, which the
  fast-forward exporter emits retroactively, so they are checked for
  containment in the file's time range instead).

Exit status is nonzero if any file fails; errors name the file, the
event index, and the violated rule, so a CI failure pinpoints the
exporter bug. ``--self-test`` validates built-in synthetic documents —
both ones that must pass and ones that must fail — and exits nonzero on
any wrong verdict, same insurance as ``bench_gate.py --self-test``.
"""

import argparse
import json
import sys

VALID_PH = {"B", "E", "X", "i", "M"}
VALID_SCOPES = {"g", "p", "t"}


def check_doc(doc, label="<doc>"):
    """Validate one parsed trace document; returns a list of errors."""
    errors = []

    def err(i, msg):
        errors.append(f"{label}: event {i}: {msg}")

    if not isinstance(doc, dict):
        return [f"{label}: top level is not an object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{label}: no traceEvents list"]

    # (pid, tid) -> stack of open B names / last seen ts.
    stacks = {}
    last_ts = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            err(i, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in VALID_PH:
            err(i, f"bad ph {ph!r}")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            err(i, "missing or empty name")
        if ph == "M":
            continue  # metadata events carry no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) or ts < 0:
            err(i, f"bad ts {ts!r}")
            continue
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool) \
                    or dur < 0:
                err(i, f"X event with bad dur {dur!r}")
            # Retroactively-emitted spans: not required to be in stream
            # order, but they must not precede the track's origin.
            continue
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            err(i, f"ts went backwards on track {track} ({ts} < {prev})")
        last_ts[track] = ts
        if ph == "B":
            stacks.setdefault(track, []).append((i, name))
        elif ph == "E":
            stack = stacks.get(track, [])
            if not stack:
                err(i, f"E {name!r} with no open B on track {track}")
            else:
                _, open_name = stack.pop()
                if open_name != name:
                    err(i, f"E {name!r} closes B {open_name!r} on "
                           f"track {track}")
        elif ph == "i":
            scope = ev.get("s")
            if scope not in VALID_SCOPES:
                err(i, f"instant with bad scope {scope!r}")
    for track, stack in stacks.items():
        for i, name in stack:
            errors.append(f"{label}: event {i}: B {name!r} on track {track} "
                          f"never closed")
    return errors


def check_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or not JSON: {e}"]
    return check_doc(doc, label=path)


def self_test():
    """Validate built-in documents with known verdicts."""
    def doc(events):
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def ev(ph, name="x", ts=0, **kw):
        e = {"ph": ph, "name": name, "pid": 0, "tid": 0, "ts": ts}
        e.update(kw)
        return e

    cases = [
        ("empty trace passes", doc([]), True),
        ("balanced B/E with instants and metadata passes",
         doc([{"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
               "args": {"name": "cell"}},
              ev("B", "frag t0 u0", 10),
              ev("i", "commit", 12, s="t"),
              ev("E", "frag t0 u0", 20),
              ev("X", "ff off", 20, dur=5000)]),
         True),
        ("nested B/E of different names passes",
         doc([ev("B", "outer", 0), ev("B", "inner", 1),
              ev("E", "inner", 2), ev("E", "outer", 3)]),
         True),
        ("top level not an object fails", [], False),
        ("missing traceEvents fails", {"displayTimeUnit": "ms"}, False),
        ("unknown phase fails", doc([ev("Q")]), False),
        ("missing name fails", doc([{"ph": "i", "pid": 0, "tid": 0,
                                     "ts": 0, "s": "t"}]), False),
        ("negative ts fails", doc([ev("i", ts=-1, s="t")]), False),
        ("non-numeric ts fails", doc([ev("i", ts="soon", s="t")]), False),
        ("unclosed B fails", doc([ev("B", "frag", 0)]), False),
        ("E without B fails", doc([ev("E", "frag", 0)]), False),
        ("mismatched E name fails",
         doc([ev("B", "a", 0), ev("E", "b", 1)]), False),
        ("B/E cross tracks fails",
         doc([ev("B", "a", 0), {"ph": "E", "name": "a", "pid": 0,
                                "tid": 1, "ts": 1}]), False),
        ("backwards ts on one track fails",
         doc([ev("i", "a", 10, s="t"), ev("i", "b", 5, s="t")]), False),
        ("same ts twice passes",
         doc([ev("i", "a", 10, s="t"), ev("i", "b", 10, s="t")]), True),
        ("instant without scope fails", doc([ev("i", ts=0)]), False),
        ("instant with bad scope fails", doc([ev("i", ts=0, s="z")]), False),
        ("X without dur fails", doc([ev("X", ts=0)]), False),
        ("X with negative dur fails", doc([ev("X", ts=0, dur=-1)]), False),
        ("X out of stream order passes (retroactive spans)",
         doc([ev("i", "a", 100, s="t"), ev("X", "ff", 0, dur=50)]), True),
    ]
    bad = 0
    for name, d, want_ok in cases:
        errors = check_doc(d, label=name)
        ok = not errors
        if ok != want_ok:
            detail = "; ".join(errors) if errors else "no errors"
            print(f"self-test FAILED: `{name}` -> {detail} "
                  f"(wanted {'pass' if want_ok else 'fail'})",
                  file=sys.stderr)
            bad += 1
    if bad:
        print(f"trace-check --self-test: {bad}/{len(cases)} cases FAILED",
              file=sys.stderr)
        return 1
    print(f"trace-check --self-test: all {len(cases)} cases passed")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*", help="Chrome trace JSON files")
    ap.add_argument("--self-test", action="store_true",
                    help="validate built-in synthetic documents and verify "
                         "every verdict")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if not args.files:
        ap.error("at least one trace file is required unless --self-test")

    bad = 0
    for path in args.files:
        errors = check_file(path)
        if errors:
            bad += 1
            for e in errors:
                print(e, file=sys.stderr)
        else:
            print(f"{path}: OK")
    if bad:
        print(f"trace-check: {bad}/{len(args.files)} file(s) FAILED",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
