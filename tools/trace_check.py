#!/usr/bin/env python3
"""Structural validator for the Chrome ``trace_event`` JSON that
``zygarde trace --format chrome`` and ``zygarde sweep --trace-dir``
emit.

Checks, per file:

* the document is an object with a ``traceEvents`` list;
* every event has a string ``name`` and a ``ph`` in {B, E, X, i, M};
* every non-metadata event has a numeric ``ts`` >= 0;
* ``X`` (complete/duration) events carry a numeric ``dur`` >= 0;
* ``i`` (instant) events carry a scope ``s`` in {g, p, t};
* per ``(pid, tid)`` track, ``B``/``E`` events balance like brackets —
  every ``E`` closes the most recent open ``B`` of the same name, and
  nothing is left open at end of file (the exporter never nests
  fragments, but the check allows well-formed nesting);
* per ``(pid, tid)`` track, ``ts`` is monotone non-decreasing over
  B/E/i events (``X`` events are sorted by their *start*, which the
  fast-forward exporter emits retroactively, so they are checked for
  containment in the file's time range instead).

With ``--timeline`` the file is additionally validated as a *serve
timeline* (``zygarde serve --trace-out`` / ``zygarde simtest
--trace-out``, rendered by ``telemetry::timeline``):

* a ``thread_name`` metadata event must name tid 0 ``dispatcher``;
* every ``X`` event is a lease span: named ``lease <id>``, on a worker
  track (tid >= 100 with ``worker <w>`` metadata), with ``args``
  carrying numeric ``lease``/``start``/``end``/``cells`` (id matching
  the name, ``end >= start``) and an ``outcome`` in
  {``done``, ``gone``, ``unresolved``};
* instants are confined to their track's vocabulary — dispatcher:
  {``spill-run``, ``done``}; journal (tid 1): {``recover``,
  ``run-adopted``, ``finalize``} with ``recover`` carrying
  ``intact_len``/``torn_bytes``/``runs``/``n_received`` args; faults
  (tid 2): {``crash``, ``partition``, ``dcrash``, ``heal``, ``kick``,
  ``relief``}; workers: {``connect``, ``gone``, ``cells``};
* any track that carries events must also carry its ``thread_name``
  metadata (the exporter only names used tracks).

Exit status is nonzero if any file fails; errors name the file, the
event index, and the violated rule, so a CI failure pinpoints the
exporter bug. ``--self-test`` validates built-in synthetic documents —
both ones that must pass and ones that must fail — and exits nonzero on
any wrong verdict, same insurance as ``bench_gate.py --self-test``.
"""

import argparse
import json
import sys

VALID_PH = {"B", "E", "X", "i", "M"}
VALID_SCOPES = {"g", "p", "t"}

# Serve-timeline track layout (telemetry::timeline constants).
TID_DISPATCH = 0
TID_JOURNAL = 1
TID_FAULTS = 2
TID_WORKER_BASE = 100
LEASE_OUTCOMES = {"done", "gone", "unresolved"}
FAULT_KINDS = {"crash", "partition", "dcrash", "heal", "kick", "relief"}
DISPATCH_INSTANTS = {"spill-run", "done"}
JOURNAL_INSTANTS = {"recover", "run-adopted", "finalize"}
WORKER_INSTANTS = {"connect", "gone", "cells"}
RECOVER_ARG_KEYS = ("intact_len", "torn_bytes", "runs", "n_received")


def check_doc(doc, label="<doc>"):
    """Validate one parsed trace document; returns a list of errors."""
    errors = []

    def err(i, msg):
        errors.append(f"{label}: event {i}: {msg}")

    if not isinstance(doc, dict):
        return [f"{label}: top level is not an object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{label}: no traceEvents list"]

    # (pid, tid) -> stack of open B names / last seen ts.
    stacks = {}
    last_ts = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            err(i, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in VALID_PH:
            err(i, f"bad ph {ph!r}")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            err(i, "missing or empty name")
        if ph == "M":
            continue  # metadata events carry no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) or ts < 0:
            err(i, f"bad ts {ts!r}")
            continue
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool) \
                    or dur < 0:
                err(i, f"X event with bad dur {dur!r}")
            # Retroactively-emitted spans: not required to be in stream
            # order, but they must not precede the track's origin.
            continue
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            err(i, f"ts went backwards on track {track} ({ts} < {prev})")
        last_ts[track] = ts
        if ph == "B":
            stacks.setdefault(track, []).append((i, name))
        elif ph == "E":
            stack = stacks.get(track, [])
            if not stack:
                err(i, f"E {name!r} with no open B on track {track}")
            else:
                _, open_name = stack.pop()
                if open_name != name:
                    err(i, f"E {name!r} closes B {open_name!r} on "
                           f"track {track}")
        elif ph == "i":
            scope = ev.get("s")
            if scope not in VALID_SCOPES:
                err(i, f"instant with bad scope {scope!r}")
    for track, stack in stacks.items():
        for i, name in stack:
            errors.append(f"{label}: event {i}: B {name!r} on track {track} "
                          f"never closed")
    return errors


def _is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_timeline(doc, label="<doc>"):
    """Serve-timeline checks layered on top of `check_doc` (the caller
    runs both). Returns a list of errors."""
    errors = []

    def err(i, msg):
        errors.append(f"{label}: event {i}: {msg}")

    if not isinstance(doc, dict):
        return []  # check_doc already reported it
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return []

    track_names = {}  # tid -> thread_name
    used_tids = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            continue
        ph, name, tid = ev.get("ph"), ev.get("name"), ev.get("tid")
        args = ev.get("args")
        if ph == "M":
            if name == "thread_name" and isinstance(args, dict):
                track_names[tid] = args.get("name")
            continue
        used_tids.add(tid)
        if ph == "X":
            if not isinstance(tid, (int, float)) or tid < TID_WORKER_BASE:
                err(i, f"X span on non-worker track tid {tid!r} — only "
                       f"lease spans are X, and leases live on workers")
                continue
            if not isinstance(args, dict):
                err(i, f"lease span {name!r} without args")
                continue
            for k in ("lease", "start", "end", "cells"):
                if not _is_num(args.get(k)):
                    err(i, f"lease span {name!r} args lack numeric {k!r}")
            if _is_num(args.get("lease")) and \
                    name != f"lease {int(args['lease'])}":
                err(i, f"span name {name!r} does not match args.lease "
                       f"{args.get('lease')!r}")
            if _is_num(args.get("start")) and _is_num(args.get("end")) \
                    and args["end"] < args["start"]:
                err(i, f"lease span {name!r} has end < start")
            if args.get("outcome") not in LEASE_OUTCOMES:
                err(i, f"lease span {name!r} outcome "
                       f"{args.get('outcome')!r} not in "
                       f"{sorted(LEASE_OUTCOMES)}")
        elif ph == "i":
            if tid == TID_DISPATCH:
                if name not in DISPATCH_INSTANTS:
                    err(i, f"dispatcher instant {name!r} not in "
                           f"{sorted(DISPATCH_INSTANTS)}")
            elif tid == TID_JOURNAL:
                if name not in JOURNAL_INSTANTS:
                    err(i, f"journal instant {name!r} not in "
                           f"{sorted(JOURNAL_INSTANTS)}")
                elif name == "recover":
                    missing = [k for k in RECOVER_ARG_KEYS
                               if not (isinstance(args, dict)
                                       and _is_num(args.get(k)))]
                    if missing:
                        err(i, f"recover instant lacks numeric args "
                               f"{missing}")
            elif tid == TID_FAULTS:
                if name not in FAULT_KINDS:
                    err(i, f"fault marker {name!r} not in "
                           f"{sorted(FAULT_KINDS)}")
            elif isinstance(tid, (int, float)) and tid >= TID_WORKER_BASE:
                if name not in WORKER_INSTANTS:
                    err(i, f"worker instant {name!r} not in "
                           f"{sorted(WORKER_INSTANTS)}")
            else:
                err(i, f"instant {name!r} on unknown track tid {tid!r}")

    if track_names.get(TID_DISPATCH) != "dispatcher":
        errors.append(f"{label}: no thread_name metadata naming tid "
                      f"{TID_DISPATCH} 'dispatcher'")
    for tid in sorted(t for t in used_tids if isinstance(t, (int, float))):
        want = None
        if tid == TID_JOURNAL:
            want = "journal"
        elif tid == TID_FAULTS:
            want = "faults"
        elif tid >= TID_WORKER_BASE:
            want = f"worker {int(tid - TID_WORKER_BASE)}"
        if want is not None and track_names.get(tid) != want:
            errors.append(f"{label}: track tid {tid} carries events but "
                          f"lacks thread_name metadata {want!r}")
    return errors


def check_file(path, timeline=False):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or not JSON: {e}"]
    errors = check_doc(doc, label=path)
    if timeline:
        errors += check_timeline(doc, label=path)
    return errors


def self_test():
    """Validate built-in documents with known verdicts."""
    def doc(events):
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def ev(ph, name="x", ts=0, **kw):
        e = {"ph": ph, "name": name, "pid": 0, "tid": 0, "ts": ts}
        e.update(kw)
        return e

    cases = [
        ("empty trace passes", doc([]), True),
        ("balanced B/E with instants and metadata passes",
         doc([{"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
               "args": {"name": "cell"}},
              ev("B", "frag t0 u0", 10),
              ev("i", "commit", 12, s="t"),
              ev("E", "frag t0 u0", 20),
              ev("X", "ff off", 20, dur=5000)]),
         True),
        ("nested B/E of different names passes",
         doc([ev("B", "outer", 0), ev("B", "inner", 1),
              ev("E", "inner", 2), ev("E", "outer", 3)]),
         True),
        ("top level not an object fails", [], False),
        ("missing traceEvents fails", {"displayTimeUnit": "ms"}, False),
        ("unknown phase fails", doc([ev("Q")]), False),
        ("missing name fails", doc([{"ph": "i", "pid": 0, "tid": 0,
                                     "ts": 0, "s": "t"}]), False),
        ("negative ts fails", doc([ev("i", ts=-1, s="t")]), False),
        ("non-numeric ts fails", doc([ev("i", ts="soon", s="t")]), False),
        ("unclosed B fails", doc([ev("B", "frag", 0)]), False),
        ("E without B fails", doc([ev("E", "frag", 0)]), False),
        ("mismatched E name fails",
         doc([ev("B", "a", 0), ev("E", "b", 1)]), False),
        ("B/E cross tracks fails",
         doc([ev("B", "a", 0), {"ph": "E", "name": "a", "pid": 0,
                                "tid": 1, "ts": 1}]), False),
        ("backwards ts on one track fails",
         doc([ev("i", "a", 10, s="t"), ev("i", "b", 5, s="t")]), False),
        ("same ts twice passes",
         doc([ev("i", "a", 10, s="t"), ev("i", "b", 10, s="t")]), True),
        ("instant without scope fails", doc([ev("i", ts=0)]), False),
        ("instant with bad scope fails", doc([ev("i", ts=0, s="z")]), False),
        ("X without dur fails", doc([ev("X", ts=0)]), False),
        ("X with negative dur fails", doc([ev("X", ts=0, dur=-1)]), False),
        ("X out of stream order passes (retroactive spans)",
         doc([ev("i", "a", 100, s="t"), ev("X", "ff", 0, dur=50)]), True),
    ]

    # --- serve-timeline mode -------------------------------------------
    def meta(tid, name):
        return {"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                "args": {"name": name}}

    def lease_span(lid=7, tid=103, start=0, end=4, cells=4,
                   outcome="done", ts=2000, dur=7000, **arg_over):
        e = {"ph": "X", "name": f"lease {lid}", "pid": 0, "tid": tid,
             "ts": ts, "dur": dur,
             "args": {"lease": lid, "start": start, "end": end,
                      "cells": cells, "outcome": outcome}}
        e["args"].update(arg_over)
        return e

    def tev(ph, name, tid, ts=0, args=None):
        e = {"ph": ph, "name": name, "pid": 0, "tid": tid, "ts": ts}
        if ph == "i":
            e["s"] = "t"
        if args is not None:
            e["args"] = args
        return e

    base_meta = [meta(0, "dispatcher")]
    recover_args = {"intact_len": 96, "torn_bytes": 3, "runs": 2,
                    "n_received": 16}
    timeline_cases = [
        ("minimal timeline (dispatcher named) passes",
         doc(base_meta + [tev("i", "done", 0, 9,
                              args={"cells": 24})]), True),
        ("full timeline with lease span, journal, faults, worker passes",
         doc(base_meta + [meta(1, "journal"), meta(2, "faults"),
                          meta(103, "worker 3"),
                          tev("i", "connect", 103, 1),
                          tev("i", "cells", 103, 5,
                              args={"lease": 7, "n": 2}),
                          lease_span(),
                          tev("i", "recover", 1, 3, args=recover_args),
                          tev("i", "run-adopted", 1, 4, args={"cells": 8}),
                          tev("i", "finalize", 1, 8,
                              args={"n_scenarios": 16}),
                          tev("i", "dcrash", 2, 2,
                              args={"detail": "#0"}),
                          tev("i", "done", 0, 9, args={"cells": 16})]),
         True),
        ("missing dispatcher metadata fails",
         doc([tev("i", "done", 0, 9, args={"cells": 24})]), False),
        ("lease span on a non-worker track fails",
         doc(base_meta + [lease_span(tid=0)]), False),
        ("lease span without outcome fails",
         doc(base_meta + [meta(103, "worker 3"),
                          lease_span(outcome=None)]), False),
        ("lease span with unknown outcome fails",
         doc(base_meta + [meta(103, "worker 3"),
                          lease_span(outcome="maybe")]), False),
        ("lease span name/args.lease mismatch fails",
         doc(base_meta + [meta(103, "worker 3"),
                          lease_span(**{"lease": 8})]), False),
        ("lease span with end < start fails",
         doc(base_meta + [meta(103, "worker 3"),
                          lease_span(start=8, end=4)]), False),
        ("unknown fault marker fails",
         doc(base_meta + [meta(2, "faults"),
                          tev("i", "meteor", 2, 1)]), False),
        ("every accepted fault marker passes",
         doc(base_meta + [meta(2, "faults")] +
             [tev("i", k, 2, j) for j, k in
              enumerate(sorted(FAULT_KINDS))]), True),
        ("recover instant without args fails",
         doc(base_meta + [meta(1, "journal"),
                          tev("i", "recover", 1, 3)]), False),
        ("unknown journal instant fails",
         doc(base_meta + [meta(1, "journal"),
                          tev("i", "compact", 1, 3)]), False),
        ("unknown worker instant fails",
         doc(base_meta + [meta(103, "worker 3"),
                          tev("i", "naptime", 103, 1)]), False),
        ("events on an unnamed worker track fail",
         doc(base_meta + [tev("i", "connect", 103, 1)]), False),
        ("misnamed worker track fails",
         doc(base_meta + [meta(103, "worker 9"),
                          tev("i", "connect", 103, 1)]), False),
        ("instant on an unknown low tid fails",
         doc(base_meta + [tev("i", "done", 5, 1)]), False),
    ]

    bad = 0
    for name, d, want_ok in cases:
        errors = check_doc(d, label=name)
        ok = not errors
        if ok != want_ok:
            detail = "; ".join(errors) if errors else "no errors"
            print(f"self-test FAILED: `{name}` -> {detail} "
                  f"(wanted {'pass' if want_ok else 'fail'})",
                  file=sys.stderr)
            bad += 1
    for name, d, want_ok in timeline_cases:
        errors = check_doc(d, label=name) + check_timeline(d, label=name)
        ok = not errors
        if ok != want_ok:
            detail = "; ".join(errors) if errors else "no errors"
            print(f"self-test FAILED (timeline): `{name}` -> {detail} "
                  f"(wanted {'pass' if want_ok else 'fail'})",
                  file=sys.stderr)
            bad += 1
    total = len(cases) + len(timeline_cases)
    if bad:
        print(f"trace-check --self-test: {bad}/{total} cases FAILED",
              file=sys.stderr)
        return 1
    print(f"trace-check --self-test: all {total} cases passed")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*", help="Chrome trace JSON files")
    ap.add_argument("--timeline", action="store_true",
                    help="additionally validate the files as serve "
                         "timelines (lease spans, track vocabularies, "
                         "track metadata)")
    ap.add_argument("--self-test", action="store_true",
                    help="validate built-in synthetic documents and verify "
                         "every verdict")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if not args.files:
        ap.error("at least one trace file is required unless --self-test")

    bad = 0
    for path in args.files:
        errors = check_file(path, timeline=args.timeline)
        if errors:
            bad += 1
            for e in errors:
                print(e, file=sys.stderr)
        else:
            print(f"{path}: OK")
    if bad:
        print(f"trace-check: {bad}/{len(args.files)} file(s) FAILED",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
