#!/usr/bin/env python3
"""Bench regression gate for the sweep engine.

Compares a freshly measured ``BENCH_sweep.json`` (written by
``cargo bench --bench bench_sweep``) against the committed
``BENCH_baseline.json`` and fails when scenarios/sec drops more than
``--max-drop`` (default 30%) below the baseline on any comparable row
(per-thread-count, per-process-count sharded, and per-NVM-policy rows).

The comparison only runs when the workloads match (same scenario count,
per-cell horizon, and reps); otherwise it reports and exits 0, since a
ratio between different workloads is meaningless.

Bootstrapping: a baseline carrying ``"provisional": true`` (committed
from a machine that could not run the bench) reports the comparison but
never fails. To arm the gate, download CI's ``bench-sweep`` artifact and
commit its ``BENCH_sweep.json`` as ``BENCH_baseline.json`` with the
``provisional`` key removed.
"""

import argparse
import json
import sys


def rows(doc):
    out = {}
    for r in doc.get("threads", []):
        out[f"threads={int(r['threads'])}"] = r["scenarios_per_s"]
    for r in doc.get("sharded", []):
        out[f"processes={int(r['processes'])}"] = r["scenarios_per_s"]
    for r in doc.get("nvm_policies", []):
        out[f"nvm={r['policy']}"] = r["scenarios_per_s"]
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_sweep.json")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="maximum tolerated fractional throughput drop (default 0.30)")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    mismatch = [k for k in ("scenarios", "duration_ms", "reps")
                if cur.get(k) != base.get(k)]
    if mismatch:
        print(f"bench-gate: workload mismatch on {mismatch} "
              f"(current {[cur.get(k) for k in mismatch]} vs "
              f"baseline {[base.get(k) for k in mismatch]}); skipping comparison")
        return 0

    provisional = bool(base.get("provisional"))
    crows, brows = rows(cur), rows(base)
    failures = []
    print(f"{'row':<24} {'baseline':>12} {'current':>12} {'ratio':>9}")
    for key, b in sorted(brows.items()):
        c = crows.get(key)
        if c is None:
            print(f"{key:<24} {b:>12.1f} {'missing':>12}")
            failures.append(f"{key}: row missing from current run")
            continue
        ratio = c / b if b > 0 else float("inf")
        flag = "" if ratio >= 1.0 - args.max_drop else "  << DROP"
        print(f"{key:<24} {b:>12.1f} {c:>12.1f} {ratio:>8.2f}x{flag}")
        if ratio < 1.0 - args.max_drop:
            failures.append(f"{key}: {c:.1f}/s vs baseline {b:.1f}/s ({ratio:.2f}x)")

    if failures:
        msg = "; ".join(failures)
        if provisional:
            print(f"bench-gate: would fail ({msg}) but the baseline is marked "
                  f"provisional — commit a CI-measured BENCH_sweep.json as "
                  f"BENCH_baseline.json (without 'provisional') to arm the gate")
            return 0
        print(f"bench-gate: FAIL: {msg}", file=sys.stderr)
        return 1
    print("bench-gate: OK — no row dropped more than "
          f"{args.max_drop:.0%} below baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
