#!/usr/bin/env python3
"""Bench regression gate for the sweep engine.

Compares a freshly measured ``BENCH_sweep.json`` (written by
``cargo bench --bench bench_sweep``) against the committed
``BENCH_baseline.json`` and fails when scenarios/sec drops more than
``--max-drop`` (default 30%) below the baseline on any comparable row
(per-thread-count, per-process-count sharded, and per-NVM-policy rows).

The comparison only runs when the workloads match (same scenario count,
per-cell horizon, and reps); otherwise it reports and exits 0, since a
ratio between different workloads is meaningless.

Bootstrapping: a baseline carrying ``"provisional": true`` (committed
from a machine that could not run the bench) reports the comparison but
never fails. To arm the gate, download CI's ``bench-sweep`` artifact,
commit its ``BENCH_sweep.json`` as ``BENCH_baseline.json`` with the
``provisional`` key removed — and copy each ``offphase`` row's
``min_speedup`` key over from the old baseline (the measured file
carries ``speedup``, not floors; a baseline offphase row *without*
``min_speedup`` is a hard error so the floors cannot be disarmed by
accident).

The ``offphase`` rows are gated differently — and unconditionally. Each
baseline row carries a ``min_speedup``: the measured ratio of the naive
reference stepper's wall-clock to the optimized engine's on the same
matrix (a within-run ratio, so it is machine-independent and needs no
committed absolute numbers). A current run whose speedup falls below the
floor fails even against a provisional baseline: it means an engine
fast-forward path regressed. Every baseline offphase row must pin its
workload with ``scenarios`` and ``duration_ms`` — a row lacking either
is a hard error, because without them a silent bench-workload change
could keep a stale floor "passing" against a different matrix.

The ``trace`` and ``registry`` rows are gated like the offphase floors
— unconditionally, provisional or not. Each baseline row pins its
workload and carries a ``max_overhead``: the measured wall-clock ratio
of an enabled run (``trace``: null sink attached; ``registry``: metrics
registry attached — both strictly more work than the disabled path) to
a disabled run of the same matrix, again a within-run ratio needing no
committed absolutes. A current overhead above the ceiling fails: it
means that observability layer's disabled path is no longer ~free.

``--self-test`` runs the gate against built-in synthetic documents
covering every verdict (pass, floor breach, disarmed floor, missing
workload keys, drift, provisional, throughput drop, trace- and
registry-overhead breach) and exits nonzero if any scenario produces
the wrong verdict — cheap CI insurance that the gate itself cannot rot
into a silent no-op.
"""

import argparse
import json
import sys

OFFPHASE_WORKLOAD_KEYS = ("scenarios", "duration_ms")
TRACE_WORKLOAD_KEYS = ("scenarios", "duration_ms")


def rows(doc):
    out = {}
    for r in doc.get("threads", []):
        out[f"threads={int(r['threads'])}"] = r["scenarios_per_s"]
    for r in doc.get("sharded", []):
        out[f"processes={int(r['processes'])}"] = r["scenarios_per_s"]
    for r in doc.get("serve", []):
        out[f"serve={int(r['workers'])}"] = r["scenarios_per_s"]
    for r in doc.get("nvm_policies", []):
        out[f"nvm={r['policy']}"] = r["scenarios_per_s"]
    return out


def check_offphase_speedups(cur, base):
    """Enforce each baseline offphase row's min_speedup floor (armed
    regardless of the provisional flag: a within-run ratio needs no
    committed absolute measurement). A baseline row lacking min_speedup
    is itself a failure — promoting CI's measured BENCH_sweep.json
    verbatim (its rows carry 'speedup', no floors) must fail loudly
    rather than silently disarm the only armed gate. A baseline row
    lacking the workload keys (scenarios, duration_ms) is equally a hard
    error: the drift check below is what keeps a floor honest when the
    bench workload changes, and it cannot fire on keys that are absent.
    A row whose workload keys drifted from the baseline is a hard error
    too: a floor set for a different matrix/horizon is not comparable,
    and the PR that changes the bench workload must update (and
    re-justify) the baseline row in the same change. Returns failures."""
    current = {r["matrix"]: r for r in cur.get("offphase", [])}
    failures = []
    for row in base.get("offphase", []):
        name, floor = row["matrix"], row.get("min_speedup")
        if floor is None:
            print(f"offphase {name:<16} baseline row has no min_speedup")
            failures.append(
                f"offphase {name}: baseline row lacks min_speedup — copy the "
                f"floors over when promoting a measured BENCH_sweep.json")
            continue
        unpinned = [k for k in OFFPHASE_WORKLOAD_KEYS if k not in row]
        if unpinned:
            print(f"offphase {name:<16} baseline row missing workload keys "
                  f"{unpinned}")
            failures.append(
                f"offphase {name}: baseline row lacks {unpinned} — every "
                f"floor must pin its workload so drift cannot pass unseen")
            continue
        got = current.get(name)
        if got is None:
            print(f"offphase {name:<16} speedup floor {floor:.2f}x {'missing':>12}")
            failures.append(f"offphase {name}: row missing from current run")
            continue
        drifted = [k for k in OFFPHASE_WORKLOAD_KEYS
                   if row.get(k) != got.get(k)]
        if drifted:
            print(f"offphase {name:<16} workload drifted on {drifted} "
                  f"(baseline {[row.get(k) for k in drifted]} vs current "
                  f"{[got.get(k) for k in drifted]})")
            failures.append(
                f"offphase {name}: bench workload drifted on {drifted} — the "
                f"floor is not comparable; update the baseline row alongside "
                f"the bench change")
            continue
        speedup = got.get("speedup")
        if speedup is None:
            print(f"offphase {name:<16} current row has no measured speedup")
            failures.append(
                f"offphase {name}: current row lacks `speedup` — the bench "
                f"must measure fast vs reference on every gated matrix")
            continue
        flag = "" if speedup >= floor else "  << BELOW FLOOR"
        print(f"offphase {name:<16} speedup floor {floor:.2f}x "
              f"measured {speedup:6.2f}x{flag}")
        if speedup < floor:
            failures.append(
                f"offphase {name}: fast-forward speedup {speedup:.2f}x "
                f"fell below the {floor:.2f}x floor")
    return failures


def check_overhead_ceilings(cur, base, section):
    """Enforce each baseline row's max_overhead ceiling in `section`
    ("trace" or "registry"; armed regardless of the provisional flag:
    like the offphase floors it is a within-run ratio). The same hard
    errors apply — a baseline row without max_overhead or the workload
    keys, workload drift, a missing current row, or a current row
    without a measured overhead all fail loudly rather than silently
    disarm the gate. Returns failures."""
    current = {r["matrix"]: r for r in cur.get(section, [])}
    failures = []
    for row in base.get(section, []):
        name, ceiling = row["matrix"], row.get("max_overhead")
        if ceiling is None:
            print(f"{section:<8} {name:<16} baseline row has no max_overhead")
            failures.append(
                f"{section} {name}: baseline row lacks max_overhead — keep "
                f"the ceiling when promoting a measured BENCH_sweep.json")
            continue
        unpinned = [k for k in TRACE_WORKLOAD_KEYS if k not in row]
        if unpinned:
            print(f"{section:<8} {name:<16} baseline row missing workload "
                  f"keys {unpinned}")
            failures.append(
                f"{section} {name}: baseline row lacks {unpinned} — every "
                f"ceiling must pin its workload so drift cannot pass unseen")
            continue
        got = current.get(name)
        if got is None:
            print(f"{section:<8} {name:<16} overhead ceiling {ceiling:.2f}x "
                  f"{'missing':>12}")
            failures.append(f"{section} {name}: row missing from current run")
            continue
        drifted = [k for k in TRACE_WORKLOAD_KEYS
                   if row.get(k) != got.get(k)]
        if drifted:
            print(f"{section:<8} {name:<16} workload drifted on {drifted} "
                  f"(baseline {[row.get(k) for k in drifted]} vs current "
                  f"{[got.get(k) for k in drifted]})")
            failures.append(
                f"{section} {name}: bench workload drifted on {drifted} — "
                f"the ceiling is not comparable; update the baseline row "
                f"alongside the bench change")
            continue
        overhead = got.get("overhead")
        if overhead is None:
            print(f"{section:<8} {name:<16} current row has no measured "
                  f"overhead")
            failures.append(
                f"{section} {name}: current row lacks `overhead` — the bench "
                f"must measure enabled vs disabled on every gated matrix")
            continue
        flag = "" if overhead <= ceiling else "  << ABOVE CEILING"
        print(f"{section:<8} {name:<16} overhead ceiling {ceiling:.2f}x "
              f"measured {overhead:6.3f}x{flag}")
        if overhead > ceiling:
            failures.append(
                f"{section} {name}: overhead {overhead:.3f}x exceeded "
                f"the {ceiling:.2f}x ceiling")
    return failures


def run_gate(cur, base, max_drop):
    """Gate `cur` against `base`; returns the process exit code."""
    # The offphase speedup floors and the trace/registry overhead
    # ceilings are workload- and machine-independent: check them first,
    # and unconditionally.
    off_failures = check_offphase_speedups(cur, base)
    off_failures += check_overhead_ceilings(cur, base, "trace")
    off_failures += check_overhead_ceilings(cur, base, "registry")

    mismatch = [k for k in ("scenarios", "duration_ms", "reps")
                if cur.get(k) != base.get(k)]
    if mismatch:
        print(f"bench-gate: workload mismatch on {mismatch} "
              f"(current {[cur.get(k) for k in mismatch]} vs "
              f"baseline {[base.get(k) for k in mismatch]}); skipping "
              f"throughput comparison")
        if off_failures:
            print(f"bench-gate: FAIL: {'; '.join(off_failures)}", file=sys.stderr)
            return 1
        return 0

    provisional = bool(base.get("provisional"))
    crows, brows = rows(cur), rows(base)
    failures = []
    print(f"{'row':<24} {'baseline':>12} {'current':>12} {'ratio':>9}")
    for key, b in sorted(brows.items()):
        c = crows.get(key)
        if c is None:
            print(f"{key:<24} {b:>12.1f} {'missing':>12}")
            failures.append(f"{key}: row missing from current run")
            continue
        ratio = c / b if b > 0 else float("inf")
        flag = "" if ratio >= 1.0 - max_drop else "  << DROP"
        print(f"{key:<24} {b:>12.1f} {c:>12.1f} {ratio:>8.2f}x{flag}")
        if ratio < 1.0 - max_drop:
            failures.append(f"{key}: {c:.1f}/s vs baseline {b:.1f}/s ({ratio:.2f}x)")

    if failures and provisional:
        print(f"bench-gate: would fail ({'; '.join(failures)}) but the "
              f"baseline is marked provisional — commit a CI-measured "
              f"BENCH_sweep.json as BENCH_baseline.json (without "
              f"'provisional') to arm the absolute-throughput gate")
        failures = []
    failures += off_failures
    if failures:
        print(f"bench-gate: FAIL: {'; '.join(failures)}", file=sys.stderr)
        return 1
    print(f"bench-gate: OK — no row dropped more than {max_drop:.0%} "
          f"below baseline, every offphase speedup floor held, and every "
          f"trace/registry overhead ceiling held")
    return 0


def self_test():
    """Exercise every gate verdict on synthetic documents."""
    def off_row(name, speedup=None, floor=None, scenarios=3, duration=3.6e6,
                drop_keys=()):
        row = {"matrix": name, "scenarios": scenarios, "duration_ms": duration}
        if speedup is not None:
            row["speedup"] = speedup
        if floor is not None:
            row["min_speedup"] = floor
        for k in drop_keys:
            row.pop(k, None)
        return row

    def trace_row(name, overhead=None, ceiling=None, scenarios=24,
                  duration=4000.0, drop_keys=()):
        row = {"matrix": name, "scenarios": scenarios, "duration_ms": duration}
        if overhead is not None:
            row["overhead"] = overhead
        if ceiling is not None:
            row["max_overhead"] = ceiling
        for k in drop_keys:
            row.pop(k, None)
        return row

    def doc(offphase, threads=(), workload=(64, 4000.0, 1), provisional=False,
            trace=(), registry=()):
        d = {"scenarios": workload[0], "duration_ms": workload[1],
             "reps": workload[2],
             "threads": [{"threads": t, "scenarios_per_s": s}
                         for (t, s) in threads],
             "offphase": offphase,
             "trace": list(trace),
             "registry": list(registry)}
        if provisional:
            d["provisional"] = True
        return d

    cases = [
        ("clean pass",
         doc([off_row("rf", speedup=5.0)], threads=[(1, 100.0)]),
         doc([off_row("rf", floor=2.0)], threads=[(1, 100.0)]),
         0),
        ("floor breach fails even against a provisional baseline",
         doc([off_row("rf", speedup=1.1)], threads=[(1, 100.0)]),
         doc([off_row("rf", floor=2.0)], threads=[(1, 100.0)],
             provisional=True),
         1),
        ("baseline row without min_speedup is a hard error",
         doc([off_row("rf", speedup=5.0)]),
         doc([off_row("rf")]),
         1),
        ("baseline row without scenarios is a hard error",
         doc([off_row("rf", speedup=5.0)]),
         doc([off_row("rf", floor=2.0, drop_keys=("scenarios",))]),
         1),
        ("baseline row without duration_ms is a hard error",
         doc([off_row("rf", speedup=5.0)]),
         doc([off_row("rf", floor=2.0, drop_keys=("duration_ms",))]),
         1),
        ("workload drift on an offphase row is a hard error",
         doc([off_row("rf", speedup=5.0, scenarios=9)]),
         doc([off_row("rf", floor=2.0, scenarios=3)]),
         1),
        ("offphase row missing from the current run is a hard error",
         doc([]),
         doc([off_row("rf", floor=2.0)]),
         1),
        ("current row without a measured speedup is a hard error",
         doc([off_row("rf")]),
         doc([off_row("rf", floor=2.0)]),
         1),
        ("provisional baseline reports throughput drops without failing",
         doc([off_row("rf", speedup=5.0)], threads=[(1, 10.0)]),
         doc([off_row("rf", floor=2.0)], threads=[(1, 100.0)],
             provisional=True),
         0),
        ("armed baseline fails on a throughput drop",
         doc([off_row("rf", speedup=5.0)], threads=[(1, 10.0)]),
         doc([off_row("rf", floor=2.0)], threads=[(1, 100.0)]),
         1),
        ("offphase floors stay armed across a workload mismatch",
         doc([off_row("rf", speedup=1.1)], workload=(8, 1000.0, 1)),
         doc([off_row("rf", floor=2.0)], workload=(64, 4000.0, 1)),
         1),
        ("workload mismatch alone skips the throughput gate",
         doc([off_row("rf", speedup=5.0)], threads=[(1, 10.0)],
             workload=(8, 1000.0, 1)),
         doc([off_row("rf", floor=2.0)], threads=[(1, 100.0)],
             workload=(64, 4000.0, 1)),
         0),
        ("trace overhead under the ceiling passes",
         doc([], trace=[trace_row("bench", overhead=1.005)]),
         doc([], trace=[trace_row("bench", ceiling=1.02)]),
         0),
        ("trace overhead breach fails even against a provisional baseline",
         doc([], trace=[trace_row("bench", overhead=1.09)]),
         doc([], trace=[trace_row("bench", ceiling=1.02)], provisional=True),
         1),
        ("baseline trace row without max_overhead is a hard error",
         doc([], trace=[trace_row("bench", overhead=1.0)]),
         doc([], trace=[trace_row("bench")]),
         1),
        ("baseline trace row without workload keys is a hard error",
         doc([], trace=[trace_row("bench", overhead=1.0)]),
         doc([], trace=[trace_row("bench", ceiling=1.02,
                                  drop_keys=("duration_ms",))]),
         1),
        ("trace workload drift is a hard error",
         doc([], trace=[trace_row("bench", overhead=1.0, scenarios=96)]),
         doc([], trace=[trace_row("bench", ceiling=1.02, scenarios=24)]),
         1),
        ("trace row missing from the current run is a hard error",
         doc([], trace=[]),
         doc([], trace=[trace_row("bench", ceiling=1.02)]),
         1),
        ("current trace row without a measured overhead is a hard error",
         doc([], trace=[trace_row("bench")]),
         doc([], trace=[trace_row("bench", ceiling=1.02)]),
         1),
        ("trace ceilings stay armed across a workload mismatch",
         doc([], trace=[trace_row("bench", overhead=1.09)],
             workload=(8, 1000.0, 1)),
         doc([], trace=[trace_row("bench", ceiling=1.02)],
             workload=(64, 4000.0, 1)),
         1),
        ("registry overhead under the ceiling passes",
         doc([], registry=[trace_row("bench", overhead=1.008)]),
         doc([], registry=[trace_row("bench", ceiling=1.02)]),
         0),
        ("registry overhead breach fails even against a provisional baseline",
         doc([], registry=[trace_row("bench", overhead=1.07)]),
         doc([], registry=[trace_row("bench", ceiling=1.02)],
             provisional=True),
         1),
        ("baseline registry row without max_overhead is a hard error",
         doc([], registry=[trace_row("bench", overhead=1.0)]),
         doc([], registry=[trace_row("bench")]),
         1),
        ("registry row missing from the current run is a hard error",
         doc([], registry=[]),
         doc([], registry=[trace_row("bench", ceiling=1.02)]),
         1),
        ("registry workload drift is a hard error",
         doc([], registry=[trace_row("bench", overhead=1.0, scenarios=96)]),
         doc([], registry=[trace_row("bench", ceiling=1.02, scenarios=24)]),
         1),
        ("trace and registry ceilings gate independently",
         doc([], trace=[trace_row("bench", overhead=1.005)],
             registry=[trace_row("bench", overhead=1.09)]),
         doc([], trace=[trace_row("bench", ceiling=1.02)],
             registry=[trace_row("bench", ceiling=1.02)]),
         1),
    ]
    bad = 0
    for name, cur, base, want in cases:
        print(f"--- self-test: {name}")
        got = run_gate(cur, base, 0.30)
        if got != want:
            print(f"self-test FAILED: `{name}` returned {got}, wanted {want}",
                  file=sys.stderr)
            bad += 1
    if bad:
        print(f"bench-gate --self-test: {bad}/{len(cases)} cases FAILED",
              file=sys.stderr)
        return 1
    print(f"bench-gate --self-test: all {len(cases)} cases passed")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", help="fresh BENCH_sweep.json")
    ap.add_argument("baseline", nargs="?", help="committed BENCH_baseline.json")
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="maximum tolerated fractional throughput drop (default 0.30)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the gate against built-in synthetic documents "
                         "and verify every verdict")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if args.current is None or args.baseline is None:
        ap.error("current and baseline are required unless --self-test")

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    return run_gate(cur, base, args.max_drop)


if __name__ == "__main__":
    sys.exit(main())
