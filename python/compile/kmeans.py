"""Semi-supervised k-means classifier construction (paper §4.3).

For every layer of a trained agile DNN we build one classifier:

  1. run the training set through the network and collect the layer's
     flattened activations;
  2. select the top-F features by a class-separation score (the paper uses
     SelectKBest + chi2; chi2 requires non-negative counts, so we use the
     equivalent-for-our-purpose Fisher score: between-class variance over
     within-class variance — both rank features by how well a 1-D split
     separates classes);
  3. seed k = n_classes centroids at the labeled class means (semi-
     supervised seeding, Basu et al. [23]), run a few constrained Lloyd
     iterations, and label each centroid by the majority class of its
     members;
  4. sweep the utility threshold (|d2 - d1| on L1 distances) on a held-out
     split to produce the Fig. 8 trade-off curve, and pick the smallest
     threshold whose exit-here accuracy is within `acc_tolerance` of the
     best achievable (the paper's "desired minimum inference accuracy").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

__all__ = ["LayerClassifier", "build_classifiers", "collect_features",
           "threshold_curve"]


@dataclass
class LayerClassifier:
    """One per-layer semi-supervised k-means classifier."""

    feat_idx: np.ndarray        # (F,) int32 — selected flat-activation indices
    centroids: np.ndarray       # (k, F) f32
    centroid_label: np.ndarray  # (k,) int32 — class label per centroid
    threshold: float            # utility-test threshold on |d2 - d1|
    # Fig. 8 curve: rows of (threshold, exit_rate, exit_accuracy)
    curve: List[Tuple[float, float, float]] = field(default_factory=list)


def collect_features(spec: M.NetSpec, params, x: np.ndarray,
                     batch: int = 64) -> List[np.ndarray]:
    """Per-layer flattened activations for every sample. Returns a list of
    (N, flat_i) arrays, one per layer."""
    fwd = jax.jit(
        jax.vmap(lambda a: [o.reshape(-1) for o in
                            M.forward_all_layers(spec, params, a)])
    )
    outs: List[List[np.ndarray]] = []
    for s in range(0, len(x), batch):
        chunk = fwd(jnp.asarray(x[s:s + batch]))
        outs.append([np.asarray(c) for c in chunk])
    return [np.concatenate([o[i] for o in outs]) for i in range(spec.n_layers)]


def _fisher_scores(feats: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Between-class over within-class variance per feature."""
    classes = np.unique(y)
    overall = feats.mean(axis=0)
    between = np.zeros(feats.shape[1], dtype=np.float64)
    within = np.zeros(feats.shape[1], dtype=np.float64)
    for c in classes:
        fc = feats[y == c]
        between += len(fc) * (fc.mean(axis=0) - overall) ** 2
        within += ((fc - fc.mean(axis=0)) ** 2).sum(axis=0)
    return (between / (within + 1e-9)).astype(np.float32)


def _select_features(feats: np.ndarray, y: np.ndarray, n: int) -> np.ndarray:
    n = min(n, feats.shape[1])
    scores = _fisher_scores(feats, y)
    return np.sort(np.argsort(-scores)[:n]).astype(np.int32)


def _seeded_kmeans(feats: np.ndarray, y: np.ndarray, iters: int = 8
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """k-means with labeled seeding; k = number of classes. L1 metric
    (matching the runtime classifier) => median update is the L1-optimal
    centroid; we use the mean like the paper's runtime update rule does."""
    classes = np.unique(y)
    centroids = np.stack([feats[y == c].mean(axis=0) for c in classes])
    for _ in range(iters):
        d = np.abs(feats[:, None, :] - centroids[None, :, :]).sum(axis=2)
        assign = d.argmin(axis=1)
        for j in range(len(classes)):
            members = feats[assign == j]
            if len(members):
                centroids[j] = members.mean(axis=0)
    labels = np.empty(len(classes), dtype=np.int32)
    d = np.abs(feats[:, None, :] - centroids[None, :, :]).sum(axis=2)
    assign = d.argmin(axis=1)
    for j in range(len(classes)):
        members = y[assign == j]
        labels[j] = np.bincount(members, minlength=classes.max() + 1).argmax() \
            if len(members) else classes[j]
    return centroids.astype(np.float32), labels


def _classify(centroids: np.ndarray, labels: np.ndarray, feats: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized L1 classify; returns (predicted_class, |d2-d1| gap)."""
    d = np.abs(feats[:, None, :] - centroids[None, :, :]).sum(axis=2)
    order = np.argsort(d, axis=1)
    top1 = d[np.arange(len(d)), order[:, 0]]
    top2 = d[np.arange(len(d)), order[:, 1]] if d.shape[1] > 1 else top1
    return labels[order[:, 0]], (top2 - top1).astype(np.float32)


def threshold_curve(gap: np.ndarray, pred: np.ndarray, y: np.ndarray,
                    n_points: int = 24) -> List[Tuple[float, float, float]]:
    """(threshold, exit_rate, exit_accuracy) sweep — the Fig. 8 curve."""
    qs = np.linspace(0.0, 1.0, n_points)
    out = []
    for q in qs:
        thr = float(np.quantile(gap, q)) if len(gap) else 0.0
        exits = gap >= thr
        rate = float(exits.mean())
        acc = float((pred[exits] == y[exits]).mean()) if exits.any() else 0.0
        out.append((thr, rate, acc))
    return out


def build_classifiers(spec: M.NetSpec, params, train_x: np.ndarray,
                      train_y: np.ndarray, acc_tolerance: float = 0.03,
                      val_frac: float = 0.25, seed: int = 0
                      ) -> List[LayerClassifier]:
    """Construct one LayerClassifier per layer of the agile DNN."""
    rng = np.random.default_rng(seed)
    n = len(train_x)
    perm = rng.permutation(n)
    n_val = max(int(n * val_frac), spec.n_classes * 2)
    val_i, fit_i = perm[:n_val], perm[n_val:]

    all_feats = collect_features(spec, params, train_x)
    classifiers: List[LayerClassifier] = []
    for li in range(spec.n_layers):
        flat = all_feats[li]
        idx = _select_features(flat[fit_i], train_y[fit_i], spec.n_features)
        fit_f = flat[np.ix_(fit_i, idx)]
        val_f = flat[np.ix_(val_i, idx)]
        centroids, labels = _seeded_kmeans(fit_f, train_y[fit_i])
        pred, gap = _classify(centroids, labels, val_f)
        curve = threshold_curve(gap, pred, train_y[val_i])
        best_acc = max(a for _, _, a in curve)
        thr = next(
            (t for t, _, a in curve if a >= best_acc - acc_tolerance),
            curve[-1][0],
        )
        classifiers.append(
            LayerClassifier(idx, centroids, labels, float(thr), curve)
        )
    return classifiers
