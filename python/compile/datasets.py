"""Synthetic stand-ins for the paper's four evaluation datasets.

The session image is offline, so MNIST / ESC-10 / CIFAR-100 / Visual Wake
Words cannot be downloaded. Zygarde's evaluation does not depend on the
*content* of those datasets but on two structural properties:

  1. class structure — samples cluster by class in feature space, so a
     per-layer k-means classifier is meaningful; and
  2. a *difficulty spread* — some samples are unambiguous ("easy") and can
     be classified from shallow features (early exit at unit 1), others are
     ambiguous ("hard") and need the full network. This spread is what
     drives the dynamic mandatory/optional partition.

The generators below synthesize exactly those properties with a controllable
difficulty knob: each class has a fixed smooth template image; a sample is

    x = (1 - m) * template[c] + m * template[c'] + sigma * noise

where the mixing coefficient m and noise scale sigma grow with the sample's
difficulty d ~ Beta(a, b). Easy samples sit near their class template (the
first conv layer already separates them); hard samples sit near class
boundaries (deep layers — or nothing — separate them). DESIGN.md §1
documents this substitution.

Shapes and class counts mirror the paper's setups at reduced resolution so
that `make artifacts` trains everything on CPU in minutes:

    mnist     16x16x1, 10 classes   (paper: 28x28x1, 10)
    esc10     16x16x1, 10 classes   (paper: 1 s / 8 kHz audio -> spectrogram)
    cifar100  16x16x3,  5 classes   (paper: 32x32x3, random 5-class subsets)
    vww       16x16x3,  2 classes   (paper: person / not-person, 32x32x3)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

__all__ = ["DATASETS", "DatasetSpec", "generate", "environment_shift"]


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    height: int
    width: int
    channels: int
    n_classes: int
    n_train: int
    n_test: int
    # Beta(a, b) over per-sample difficulty in [0, 1]. a < b skews easy.
    difficulty_a: float
    difficulty_b: float
    noise: float  # base additive noise scale

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.height, self.width, self.channels)


DATASETS: Dict[str, DatasetSpec] = {
    # noise levels tuned so the final-layer accuracy lands near the paper's
    # reported numbers (MNIST 98 %, ESC-10 75 %, CIFAR-100 78 %, VWW 84 %)
    # and shallow layers are measurably worse than deep ones.
    "mnist": DatasetSpec("mnist", 16, 16, 1, 10, 800, 200, 1.2, 3.0, 0.9),
    "esc10": DatasetSpec("esc10", 16, 16, 1, 10, 700, 200, 2.2, 2.0, 1.0),
    "cifar100": DatasetSpec("cifar100", 16, 16, 3, 5, 600, 200, 2.2, 2.0, 1.5),
    "vww": DatasetSpec("vww", 16, 16, 3, 2, 800, 240, 1.8, 2.2, 1.5),
    # Fig. 23 multi-task visual workload: GTSRB-like signs + their shapes.
    "sign": DatasetSpec("sign", 16, 16, 3, 6, 600, 160, 1.8, 2.4, 1.1),
    "shape": DatasetSpec("shape", 16, 16, 3, 4, 600, 160, 1.5, 2.8, 0.9),
}


def _smooth_templates(rng: np.random.Generator, spec: DatasetSpec) -> np.ndarray:
    """Fixed per-class smooth templates: low-pass-filtered Gaussian fields.

    Smoothness matters — conv layers must be able to extract local structure,
    which white-noise templates would not provide.
    """
    h, w, c = spec.shape
    t = rng.standard_normal((spec.n_classes, h, w, c)).astype(np.float32)
    # Separable box-blur (3 passes ~ Gaussian) along H then W.
    for _ in range(3):
        t = (np.roll(t, 1, axis=1) + t + np.roll(t, -1, axis=1)) / 3.0
        t = (np.roll(t, 1, axis=2) + t + np.roll(t, -1, axis=2)) / 3.0
    # Add a class-specific 2-D sinusoid so classes differ in frequency
    # content (mimics digits/spectrograms having distinct dominant shapes).
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    for k in range(spec.n_classes):
        fy, fx = 1 + k % 4, 1 + (k // 4) % 4
        wave = np.sin(2 * np.pi * (fy * yy / h + fx * xx / w) + k)
        t[k] += 0.8 * wave[..., None].astype(np.float32)
    # Normalize each template to zero mean / unit std.
    t -= t.mean(axis=(1, 2, 3), keepdims=True)
    t /= t.std(axis=(1, 2, 3), keepdims=True) + 1e-8
    return t.astype(np.float32)


def generate(
    name: str, seed: int = 7
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Generate a dataset.

    Returns `(train_x, train_y, test_x, test_y, test_difficulty)` with
    `x: float32 [N, H, W, C]`, `y: int32 [N]`, and the per-test-sample
    difficulty (useful for oracle-exit analysis, Fig. 16).
    """
    spec = DATASETS[name]
    rng = np.random.default_rng(seed ^ hash(name) % (2**31))
    templates = _smooth_templates(rng, spec)

    def make(n: int):
        y = rng.integers(0, spec.n_classes, size=n).astype(np.int32)
        d = rng.beta(spec.difficulty_a, spec.difficulty_b, size=n).astype(np.float32)
        other = (y + 1 + rng.integers(0, spec.n_classes - 1, size=n)) % spec.n_classes
        mix = 0.5 * d  # hardest samples are 50/50 mixtures: irreducibly hard
        white = rng.standard_normal((n, *spec.shape)).astype(np.float32)
        # Spatially-correlated noise: looks like "wrong template" fragments
        # to shallow local features (pooling cannot average it out), while
        # deeper layers can learn to cancel it — this is what gives depth
        # an accuracy advantage, as in real data.
        smooth = white.copy()
        for _ in range(2):
            smooth = (np.roll(smooth, 1, 1) + smooth + np.roll(smooth, -1, 1)) / 3.0
            smooth = (np.roll(smooth, 1, 2) + smooth + np.roll(smooth, -1, 2)) / 3.0
        smooth /= smooth.std(axis=(1, 2, 3), keepdims=True) + 1e-8
        noise = 0.65 * smooth + 0.35 * white
        # Contrast inversion on hard samples: a sign flip leaves the class
        # identity unchanged (a dog bark at opposite microphone polarity is
        # still a dog bark) but defeats direct template matching — the
        # network must *learn* the invariance, which takes depth
        # (rectification + recombination). Easy samples are never flipped,
        # so they remain classifiable from layer 1: exactly the paper's
        # "required DNN computation depends on the quality of the data".
        flip = np.where(rng.random(n) < 0.5 * d, -1.0, 1.0).astype(np.float32)
        x = flip[:, None, None, None] * (
            (1.0 - mix)[:, None, None, None] * templates[y]
            + mix[:, None, None, None] * templates[other]
        ) + (spec.noise * (0.4 + d))[:, None, None, None] * noise
        return x.astype(np.float32), y, d

    train_x, train_y, _ = make(spec.n_train)
    test_x, test_y, test_d = make(spec.n_test)
    return train_x, train_y, test_x, test_y, test_d


def environment_shift(x: np.ndarray, env: int, seed: int = 99) -> np.ndarray:
    """Simulate re-recording the same clips in a different room (Fig. 24).

    The paper records the ESC-10 test split in three environments (lab,
    hall, office) and shows accuracy drops without centroid adaptation. A
    room change is, to first order, a channel effect: a gain, a DC offset,
    and a fixed additive background — i.e. an affine shift of feature space,
    precisely the class of shifts the paper says its adaptation handles
    ("translation ... of feature spaces", §11.3). Environment 0 is identity.
    """
    if env == 0:
        return x
    rng = np.random.default_rng(seed + env)
    gain = 1.0 + 0.12 * env * rng.standard_normal()
    offset = 0.25 * env
    background = rng.standard_normal(x.shape[1:]).astype(np.float32)
    # Smooth the background the same way templates are smoothed.
    for _ in range(3):
        background = (
            np.roll(background, 1, 0) + background + np.roll(background, -1, 0)
        ) / 3.0
        background = (
            np.roll(background, 1, 1) + background + np.roll(background, -1, 1)
        ) / 3.0
    return (gain * x + offset + 0.3 * env * background).astype(np.float32)
