"""AOT compile path: train → build classifiers → lower units → artifacts/.

This is the only place Python touches the system. `make artifacts` runs it
once; afterwards the Rust binary is self-contained. Per dataset it emits:

    artifacts/<name>/unit<i>.hlo.txt   # (act_in, centroids) -> (act_out, dists)
    artifacts/<name>/meta.json         # specs, costs, thresholds, curves
    artifacts/<name>/tensors.bin       # ZYGT: weights, centroids, test set

HLO **text** is the interchange format: the image's xla_extension 0.5.1
rejects jax>=0.5 serialized HloModuleProtos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Loss-ablation artifacts (Fig. 15) are exported for MNIST and ESC-10 under
``artifacts/ablation_<loss>_<name>/`` with weights + classifiers only (the
Rust native forward regenerates their traces; no HLO is needed there).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import binfmt, costs, datasets, kmeans, model as M, train as T

# Datasets whose units are lowered to HLO (the PJRT serving path).
HLO_DATASETS = ("mnist", "esc10", "cifar100", "vww", "sign", "shape")
ABLATION = (("mnist", "cross_entropy"), ("mnist", "contrastive"),
            ("esc10", "cross_entropy"), ("esc10", "contrastive"))

# Per-dataset training hyper-parameters (the paper's "exhaustive search for
# hyper-parameter tuning" distilled to what matters on the synthetic data:
# ESC-10's no-pool middle layers need the longer schedule).
TRAIN_OVERRIDES: Dict[str, Dict] = {
    "esc10": {"steps": 700, "batch": 48, "margin": 1.5},
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple()).

    GOTCHA: ``comp.as_hlo_text()`` ELIDES large constants (printing
    ``constant({...})``), which the downstream text parser silently reads
    back as zeros — the baked network weights would vanish from the
    artifact. Print through HloPrintOptions with print_large_constants=True
    instead.
    """
    from jaxlib import _jax

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = _jax.HloPrintOptions()
    opts.print_large_constants = True
    # jax >= 0.8 emits `source_end_line` etc. in metadata, which the
    # xla_extension 0.5.1 text parser rejects — strip metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_unit(spec: M.NetSpec, params, li: int, clf, act_in_shape) -> str:
    fn = M.unit_fn(spec, params, li, clf.feat_idx, use_pallas=True)
    act_spec = jax.ShapeDtypeStruct(tuple(act_in_shape), jnp.float32)
    cen_spec = jax.ShapeDtypeStruct(clf.centroids.shape, jnp.float32)
    lowered = jax.jit(fn).lower(act_spec, cen_spec)
    return to_hlo_text(lowered)


def export_dataset(name: str, out_root: str, loss: str = "layer_aware",
                   with_hlo: bool = True, seed: int = 0,
                   dirname: str | None = None) -> Dict:
    t0 = time.time()
    spec = M.NETWORKS[name]
    train_x, train_y, test_x, test_y, test_d = datasets.generate(name, seed=7)

    cfg = T.TrainConfig(loss=loss, seed=seed, **TRAIN_OVERRIDES.get(name, {}))
    params, history = T.train(spec, train_x, train_y, cfg)
    clfs = kmeans.build_classifiers(spec, params, train_x, train_y)
    cm = costs.build_cost_model(spec)
    shapes = M.layer_shapes(spec)

    dirname = dirname or name
    out_dir = os.path.join(out_root, dirname)
    os.makedirs(out_dir, exist_ok=True)

    tensors: Dict[str, np.ndarray] = {
        "test_x": test_x, "test_y": test_y, "test_d": test_d,
        "train_y_hist": np.bincount(train_y, minlength=spec.n_classes).astype(np.int32),
    }
    layers_meta: List[Dict] = []
    for li, (layer, clf, uc) in enumerate(zip(spec.layers, clfs, cm.units)):
        tensors[f"layer{li}_w"] = params[li]["w"]
        tensors[f"layer{li}_b"] = params[li]["b"]
        tensors[f"layer{li}_centroids"] = clf.centroids
        tensors[f"layer{li}_feat_idx"] = clf.feat_idx
        tensors[f"layer{li}_centroid_label"] = clf.centroid_label
        layers_meta.append({
            "kind": layer.kind, "out": layer.out, "pool": layer.pool,
            "relu": layer.relu, "act_shape": list(shapes[li]),
            "k": int(clf.centroids.shape[0]),
            "n_features": int(clf.centroids.shape[1]),
            "threshold": clf.threshold,
            "curve": [[float(a), float(b), float(c)] for a, b, c in clf.curve],
            "macs": uc.macs, "adds": uc.adds,
            "time_ms": uc.time_ms, "energy_mj": uc.energy_mj,
            "n_fragments": uc.n_fragments, "fragment_ms": uc.fragment_ms,
            "fragment_energy_mj": uc.fragment_energy_mj,
        })
        if with_hlo:
            act_in = spec.input_shape if li == 0 else shapes[li - 1]
            hlo = lower_unit(spec, params, li, clf, act_in)
            with open(os.path.join(out_dir, f"unit{li}.hlo.txt"), "w") as f:
                f.write(hlo)

    # Fig. 24: the ESC-10 test split "re-recorded" in two more environments.
    if name == "esc10":
        tensors["env1_x"] = datasets.environment_shift(test_x, 1)
        tensors["env2_x"] = datasets.environment_shift(test_x, 2)

    binfmt.write_archive(os.path.join(out_dir, "tensors.bin"), tensors)
    meta = {
        "name": name, "loss": loss,
        "input_shape": list(spec.input_shape),
        "n_classes": spec.n_classes, "n_layers": spec.n_layers,
        "n_features": spec.n_features,
        "n_test": int(len(test_x)),
        "layers": layers_meta,
        "with_hlo": with_hlo,
        "final_train_loss": float(np.mean(history[-20:])),
        "cost_model": {
            "scale": cm.scale, "e_man_mj": cm.e_man_mj,
            "total_time_ms": cm.total_time_ms,
            "total_energy_mj": cm.total_energy_mj,
            "job_generator_ms": cm.job_generator_ms,
            "job_generator_energy_mj": cm.job_generator_energy_mj,
            "scheduler_overhead_ms": cm.scheduler_overhead_ms,
            "scheduler_overhead_mj": cm.scheduler_overhead_mj,
        },
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] {dirname}: loss={loss} "
          f"train_loss={meta['final_train_loss']:.4f} "
          f"total={cm.total_time_ms:.0f}ms hlo={with_hlo} "
          f"({time.time() - t0:.1f}s)", flush=True)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root")
    ap.add_argument("--only", default=None,
                    help="comma-separated dataset subset (debugging)")
    ap.add_argument("--skip-ablation", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = args.only.split(",") if args.only else HLO_DATASETS
    for name in names:
        export_dataset(name, args.out, with_hlo=True)
    if not args.skip_ablation and not args.only:
        for name, loss in ABLATION:
            export_dataset(name, args.out, loss=loss, with_hlo=False,
                           dirname=f"ablation_{loss}_{name}")
    # Stamp for the Makefile's freshness check.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print("[aot] done")


if __name__ == "__main__":
    main()
