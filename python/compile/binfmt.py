"""ZYGT — the tiny tensor-archive format shared between the Python compile
path and the Rust runtime.

The session image has no serde on the Rust side and no need for npz/npy
compatibility, so we define the simplest self-describing container that a
few hundred lines of Rust can parse:

    magic   : 4 bytes  b"ZYGT"
    version : u32 LE   (currently 1)
    count   : u32 LE   number of entries
    entry*  :
        name_len : u32 LE
        name     : utf-8 bytes
        dtype    : u8   (0 = f32, 1 = i32)
        ndim     : u32 LE
        dims     : ndim * u64 LE
        data     : prod(dims) * 4 bytes LE

Everything is little-endian. Entries are looked up by name on the Rust
side (`rust/src/util/binfmt.rs`).
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"ZYGT"
VERSION = 1
_DTYPE_CODE = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
_CODE_DTYPE = {0: np.float32, 1: np.int32}


def write_archive(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a name->tensor mapping to `path` in ZYGT format."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPE_CODE:
                if np.issubdtype(arr.dtype, np.integer):
                    arr = arr.astype(np.int32)
                else:
                    arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPE_CODE[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read_archive(path: str) -> Dict[str, np.ndarray]:
    """Read a ZYGT archive back (used by the pytest round-trip checks)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            n = int(np.prod(dims)) if ndim else 1
            dtype = _CODE_DTYPE[code]
            data = np.frombuffer(f.read(4 * n), dtype=dtype)
            out[name] = data.reshape(dims)
    return out
