"""Compile-time MSP430 cost model (the EnergyTrace++ substitute).

The paper sets E_man by measuring, with EnergyTrace++, the maximum energy
any atomic fragment consumes, and reasons about unit execution times from
on-device profiling (Fig. 14). This module derives the same quantities from
an operation-count model of the MSP430FR5994:

  * 16 MHz core clock; software-pipelined MAC via the HW multiplier costs
    ~4x an add (the paper's own 4x claim, refs [4, 13]);
  * per-cycle active energy calibrated so a full ESC-10 inference lands at
    the paper's reported ~3 s / tens of mJ magnitude;
  * SONIC-style fragments: a unit is split into fixed-cycle-budget atomic
    fragments, each paying a FRAM commit overhead; re-executing a fragment
    after a power failure is idempotent (handled by the Rust engine).

Because our networks are channel-scaled versions of Table 3, absolute MACs
are lower than the paper's; a per-network calibration factor rescales total
inference time to the paper's reported magnitude so the *scheduling*
problem (ratios of unit cost to period, deadline, and capacitor energy) is
faithful. DESIGN.md §1 records this substitution.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from . import model as M

__all__ = ["CostModel", "build_cost_model"]

CPU_HZ = 16_000_000.0
ADD_CYCLES = 6.0          # add/sub/abs on FRAM operands
MAC_CYCLES = 4.0 * ADD_CYCLES  # the paper's 4x multiply-to-add ratio
# Active energy per (scaled) cycle. Chosen so full-throttle compute draws
# ~110 mW — between the Table 4 RF average (58–80 mW) and solar average
# (310–600 mW). This reproduces the paper's operating regime: solar systems
# stay net-positive while computing, RF systems duty-cycle (their Table 5
# power-on time is 65–77 % even for solar), and burst gaps genuinely
# exhaust the 272 mJ capacitor — i.e. intermittency has teeth in the
# scheduler experiments. The absolute value is a testbed calibration, not
# an MSP430 datasheet number (DESIGN.md §1).
ENERGY_PER_CYCLE_NJ = 6.9
FRAGMENT_CYCLES = 120_000      # SONIC task budget (~7.5 ms per fragment)
FRAGMENT_COMMIT_OVERHEAD = 0.06  # FRAM double-buffer commit per fragment

# Paper-magnitude full-inference times (ms). Fig. 14 / §9.1: ESC-10 whole
# model ~3 s; MNIST task set is run with U > 1 at T = 3 s (C > T); CIFAR
# nets are the largest; VWW smallest per Table 3 parameter counts.
TARGET_TOTAL_MS: Dict[str, float] = {
    "mnist": 3600.0,
    "esc10": 3000.0,
    "cifar100": 5200.0,
    "vww": 2400.0,
    "sign": 2000.0,
    "shape": 1000.0,
}


@dataclass
class UnitCost:
    macs: int
    adds: int            # classifier adds (k-means + utility test)
    cycles: float        # total incl. fragment commit overhead
    time_ms: float
    energy_mj: float
    n_fragments: int
    fragment_ms: float
    fragment_energy_mj: float


@dataclass
class CostModel:
    units: List[UnitCost]
    scale: float                 # calibration multiplier applied to cycles
    e_man_mj: float              # max fragment energy == paper's E_man
    job_generator_ms: float      # sensor read + FFT + FRAM write (Fig. 14)
    job_generator_energy_mj: float
    scheduler_overhead_ms: float  # per scheduler invocation (Fig. 14)
    scheduler_overhead_mj: float

    @property
    def total_time_ms(self) -> float:
        return sum(u.time_ms for u in self.units)

    @property
    def total_energy_mj(self) -> float:
        return sum(u.energy_mj for u in self.units)


def _layer_macs(spec: M.NetSpec) -> List[int]:
    macs = []
    cur = spec.input_shape
    for layer in spec.layers:
        if layer.kind == "conv":
            h, w, cin = cur
            oh, ow = h - M.KSIZE + 1, w - M.KSIZE + 1
            macs.append(oh * ow * M.KSIZE * M.KSIZE * cin * layer.out)
            if layer.pool:
                oh, ow = oh // 2, ow // 2
            cur = (oh, ow, layer.out)
        else:
            fan_in = int(np.prod(cur))
            macs.append(fan_in * layer.out)
            cur = (layer.out,)
    return macs


def build_cost_model(spec: M.NetSpec) -> CostModel:
    macs = _layer_macs(spec)
    # Classifier cost per unit: k*F subs + abs + accumulate, plus the O(k)
    # utility test — all adds.
    k = spec.n_classes
    clf_adds = 3 * k * spec.n_features + 4 * k

    raw_cycles = [m * MAC_CYCLES + clf_adds * ADD_CYCLES for m in macs]
    raw_total_ms = sum(raw_cycles) / CPU_HZ * 1e3
    target = TARGET_TOTAL_MS.get(spec.name, raw_total_ms)
    scale = target / raw_total_ms

    units: List[UnitCost] = []
    for m, rc in zip(macs, raw_cycles):
        cycles = rc * scale
        n_frag = max(1, int(np.ceil(cycles / FRAGMENT_CYCLES)))
        cycles *= 1.0 + FRAGMENT_COMMIT_OVERHEAD
        time_ms = cycles / CPU_HZ * 1e3
        energy_mj = cycles * ENERGY_PER_CYCLE_NJ * 1e-6
        units.append(
            UnitCost(
                macs=m,
                adds=clf_adds,
                cycles=cycles,
                time_ms=time_ms,
                energy_mj=energy_mj,
                n_fragments=n_frag,
                fragment_ms=time_ms / n_frag,
                fragment_energy_mj=energy_mj / n_frag,
            )
        )

    e_man = max(u.fragment_energy_mj for u in units)
    # Fig. 14: job generator reads 1 s audio, FFTs via LEA, writes FRAM in
    # 1.325 s. Image capture differs (Fig. 23) and is modeled in Rust.
    jg_ms = 1325.0 if spec.input_shape[2] == 1 else 400.0
    jg_mj = jg_ms * 1e-3 * CPU_HZ * ENERGY_PER_CYCLE_NJ * 1e-6 * 0.06  # DMA+LEA path, CPU asleep
    # Fig. 14: scheduler = 3.72 ms / 636 uJ for 3 jobs over 4N invocations.
    sched_ms = 3.72 / 12.0
    sched_mj = 0.636 / 12.0
    return CostModel(
        units=units,
        scale=scale,
        e_man_mj=e_man,
        job_generator_ms=jg_ms,
        job_generator_energy_mj=jg_mj,
        scheduler_overhead_ms=sched_ms,
        scheduler_overhead_mj=sched_mj,
    )
