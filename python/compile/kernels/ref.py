"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every Pallas kernel in this package has a reference implementation here,
written with nothing but `jax.numpy` / `jax.lax` primitives. The pytest
suite sweeps shapes and asserts `allclose(kernel, ref)`; the L2 model can
also be built entirely on these references (``use_pallas=False``) which is
what the training loop uses for speed.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) in f32."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """Extract all valid (kh, kw) patches of `x: (H, W, C)`.

    Returns `((H-kh+1)*(W-kw+1), kh*kw*C)` — the standard im2col layout so
    a convolution becomes one matmul (which is the Pallas hot-spot).
    """
    h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(lax.dynamic_slice(x, (dy, dx, 0), (oh, ow, c)))
    patches = jnp.stack(cols, axis=2)  # (oh, ow, kh*kw, c)
    return patches.reshape(oh * ow, kh * kw * c)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """VALID 2-D convolution. x: (H, W, Cin); w: (kh, kw, Cin, Cout); b: (Cout,).

    Implemented as im2col + matmul so it is bit-comparable with the Pallas
    kernel path (same contraction, up to XLA reassociation).
    """
    kh, kw, cin, cout = w.shape
    patches = im2col(x, kh, kw)  # (P, kh*kw*cin)
    out = matmul_ref(patches, w.reshape(kh * kw * cin, cout)) + b
    oh, ow = x.shape[0] - kh + 1, x.shape[1] - kw + 1
    return out.reshape(oh, ow, cout)


def maxpool2_ref(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max-pool with stride 2 (truncating odd edges). x: (H, W, C)."""
    h, w, _ = x.shape
    x = x[: h - h % 2, : w - w % 2, :]
    return lax.reduce_window(x, -jnp.inf, lax.max, (2, 2, 1), (2, 2, 1), "VALID")


def l1dist_ref(centroids: jnp.ndarray, feat: jnp.ndarray) -> jnp.ndarray:
    """L1 distances from `feat: (F,)` to each row of `centroids: (k, F)`.

    This is the paper's multiplication-free classifier: adds/subs only
    (4x cheaper than MACs on the MSP430; VPU-only on TPU).
    """
    return jnp.sum(jnp.abs(centroids - feat[None, :]), axis=1)
