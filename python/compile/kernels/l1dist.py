"""L1 Pallas kernel: multiplication-free k-means distance (the utility test).

The paper's key micro-architectural insight is that the early-exit decision
must be far cheaper than a DNN layer: it replaces the matmul-based auxiliary
classifiers of anytime networks with L1 distances to k cluster centroids —
additions and subtractions only, which are ~4x cheaper than MACs on the
MSP430 (saving 27 750 cycles per inference).

On TPU the analogous constraint is *stay off the MXU*: this kernel is pure
element-wise + row-reduction work (abs-diff then sum), which maps onto the
VPU's 8x128 lanes with no systolic-array occupancy. The centroid matrix
(k, F) is tiny (k <= 10, F <= 150 in the paper) so a single VMEM block
holds all centroids plus the feature vector; the grid is over centroid
blocks only when k is padded above the 8-row register tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["l1dist"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _l1_kernel(c_ref, x_ref, o_ref):
    # abs-diff + row-sum: VPU-only, no dot. Keepdims=1 column so the output
    # block stays 2-D (TPU-friendly layout even in interpret mode).
    o_ref[...] = jnp.sum(jnp.abs(c_ref[...] - x_ref[...]), axis=1, keepdims=True)


@jax.jit
def _l1_pallas(c: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    k, f = c.shape
    return pl.pallas_call(
        _l1_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((k, f), lambda i: (0, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=True,
    )(c, x)


def l1dist(
    centroids: jnp.ndarray, feat: jnp.ndarray, use_pallas: bool = True
) -> jnp.ndarray:
    """L1 distance of `feat: (F,)` to each of `centroids: (k, F)` -> `(k,)`.

    Rows are padded to the 8-row register tile; padded rows are sliced off
    (their distances are garbage-free since padding copies row 0).
    """
    if not use_pallas:
        return ref.l1dist_ref(centroids, feat)
    k, f = centroids.shape
    kp = _round_up(k, 8)
    c_p = jnp.pad(centroids.astype(jnp.float32), ((0, kp - k), (0, 0)))
    x_b = jnp.broadcast_to(feat.astype(jnp.float32)[None, :], (kp, f))
    return _l1_pallas(c_p, x_b)[:k, 0]
