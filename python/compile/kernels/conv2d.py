"""L1 Pallas kernel: blocked matmul — the convolution/FC hot-spot.

Zygarde's per-unit compute is dominated by one GEMM per layer (conv layers
are lowered to im2col + GEMM, FC layers are GEMMs directly). This module
provides that GEMM as a Pallas kernel so it lowers into the same HLO as the
surrounding L2 graph and ships inside the per-unit artifacts.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles for
the MSP430's 8 KB SRAM with FRAM<->SRAM DMA double-buffering; on TPU the
analogous resources are VMEM and the 128x128 MXU. The BlockSpecs below
express that schedule: A is blocked (bm, K), B is blocked (K, bn), the
output tile (bm, bn) lives in VMEM for the whole contraction, and block
sizes are clamped to multiples of the (8, 128) f32 register tile whenever
the problem is large enough to warrant it.

Kernels MUST run with ``interpret=True`` in this image: CPU PJRT cannot
execute the Mosaic custom-call a real TPU lowering would emit. Interpret
mode lowers to plain HLO which both jax-CPU and the Rust PJRT runtime
execute; structure (not interpret-mode wallclock) is what we optimize.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["matmul", "conv2d", "MXU_TILE"]

# f32 register tile on the TPU vector unit; MXU systolic array is 128x128.
MXU_TILE = (8, 128)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, pref: int) -> int:
    """Largest block <= pref that keeps the grid integral after padding."""
    return min(_round_up(dim, 8), pref)


def _matmul_kernel(a_ref, b_ref, o_ref):
    # One (bm, bn) output tile: full-K contraction while the tile is VMEM
    # resident. `preferred_element_type` pins the MXU accumulator to f32.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def matmul(a: jnp.ndarray, b: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    """`(M, K) @ (K, N)` with zero-padding to the block grid.

    Padding with zeros is exact for matmul (padded rows/cols contribute 0
    and are sliced off), so the Pallas path is numerically equivalent to
    :func:`ref.matmul_ref` up to f32 reassociation.
    """
    if not use_pallas:
        return ref.matmul_ref(a, b)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm = _pick_block(m, 64)
    bn = _pick_block(n, 128)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    a_p = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, 0)))
    b_p = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    return _matmul_pallas(a_p, b_p, bm, bn)[:m, :n]


def conv2d(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, use_pallas: bool = True
) -> jnp.ndarray:
    """VALID conv via im2col + the Pallas GEMM. Shapes as :func:`ref.conv2d_ref`."""
    if not use_pallas:
        return ref.conv2d_ref(x, w, b)
    kh, kw, cin, cout = w.shape
    patches = ref.im2col(x, kh, kw)
    out = matmul(patches, w.reshape(kh * kw * cin, cout)) + b
    oh, ow = x.shape[0] - kh + 1, x.shape[1] - kw + 1
    return out.reshape(oh, ow, cout)
