"""Offline training of agile DNNs (paper §4.2, Fig. 7).

The agile DNN is trained as a siamese network: two weight-tied copies
consume a pair of samples (50 % same-class, 50 % different-class pairs) and
the loss pushes same-class representations together and different-class
representations apart *at every layer*, so that an early exit at any depth
still lands in a cluster-friendly feature space.

Three losses are implemented because Fig. 15 compares them:

  * ``layer_aware`` (Eq. 4)  — convex combination of per-layer contrastive
    losses, coefficients a_i; this is Zygarde's proposal.
  * ``contrastive``          — contrastive loss at the last layer only
    (the SoundSemantics / Hadsell-style baseline [71]).
  * ``cross_entropy``        — a softmax head on the final embedding
    trained with CE [142]; hidden layers get no metric supervision.

Optimization is a hand-written Adam (the image has no optax); everything is
pure JAX on CPU and sized to train in seconds per network.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

__all__ = ["TrainConfig", "train", "LOSSES"]

LOSSES = ("layer_aware", "contrastive", "cross_entropy")


@dataclass
class TrainConfig:
    loss: str = "layer_aware"
    steps: int = 300
    batch: int = 32
    lr: float = 2e-3
    margin: float = 1.25  # Delta in Eq. 5
    seed: int = 0
    # Convex coefficients a_i (Eq. 4). None => uniform 1/L. The paper tunes
    # these by exhaustive search; uniform is its reported starting point.
    layer_coeffs: Tuple[float, ...] | None = None


def _normalized_embedding(act: jnp.ndarray) -> jnp.ndarray:
    """Flatten + L2-normalize a layer activation.

    Normalization keeps per-layer distance scales comparable so one margin
    works for every layer of the convex combination.
    """
    v = act.reshape(-1)
    return v / (jnp.linalg.norm(v) + 1e-6)


def _pair_contrastive(e1: jnp.ndarray, e2: jnp.ndarray, y: jnp.ndarray,
                      margin: float) -> jnp.ndarray:
    """Contrastive loss for one layer's embeddings of one pair.

    y = 0 for same class, 1 for different (the paper's Eq. 5 convention).
    """
    d = jnp.linalg.norm(e1 - e2) + 1e-9
    return 0.5 * (1.0 - y) * d**2 + 0.5 * y * jnp.maximum(0.0, margin - d) ** 2


def _siamese_loss(params, spec: M.NetSpec, x1, x2, y, coeffs, margin):
    """Batched layer-aware loss (Eq. 4). coeffs selects which layers count."""

    def per_pair(a, b, yy):
        acts1 = M.forward_all_layers(spec, params, a)
        acts2 = M.forward_all_layers(spec, params, b)
        total = 0.0
        for i, (u, v) in enumerate(zip(acts1, acts2)):
            if coeffs[i] == 0.0:
                continue
            total = total + coeffs[i] * _pair_contrastive(
                _normalized_embedding(u), _normalized_embedding(v), yy, margin
            )
        return total

    return jnp.mean(jax.vmap(per_pair)(x1, x2, y))


def _ce_loss(params_and_head, spec: M.NetSpec, x, y):
    params, head = params_and_head

    def per_sample(a):
        emb = M.forward_all_layers(spec, params, a)[-1].reshape(-1)
        return emb @ head["w"] + head["b"]

    logits = jax.vmap(per_sample)(x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _adam_init(tree):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, tree)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, tree)


def _adam_step(tree, grads, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree_util.tree_map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda a: a / (1 - b2**t), v)
    tree = jax.tree_util.tree_map(
        lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), tree, mh, vh
    )
    return tree, m, v


def _sample_pairs(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
                  batch: int):
    """50 % same-class / 50 % different-class pairs (paper §4.2)."""
    by_class: Dict[int, np.ndarray] = {
        c: np.where(y == c)[0] for c in np.unique(y)
    }
    classes = [c for c, idx in by_class.items() if len(idx) >= 2]
    i1 = np.empty(batch, np.int64)
    i2 = np.empty(batch, np.int64)
    yy = np.empty(batch, np.float32)
    for b in range(batch):
        if b % 2 == 0:  # same class
            c = classes[rng.integers(len(classes))]
            a, bb = rng.choice(by_class[c], 2, replace=False)
            yy[b] = 0.0
        else:  # different classes
            c1, c2 = rng.choice(classes, 2, replace=False)
            a = rng.choice(by_class[c1])
            bb = rng.choice(by_class[c2])
            yy[b] = 1.0
        i1[b], i2[b] = a, bb
    return x[i1], x[i2], yy


def train(spec: M.NetSpec, train_x: np.ndarray, train_y: np.ndarray,
          cfg: TrainConfig) -> Tuple[List[Dict[str, np.ndarray]], List[float]]:
    """Train one agile DNN; returns (params, loss_history)."""
    assert cfg.loss in LOSSES, cfg.loss
    rng = np.random.default_rng(cfg.seed)
    params = [
        {k: jnp.asarray(v) for k, v in p.items()}
        for p in M.init_params(spec, seed=cfg.seed)
    ]

    if cfg.loss == "cross_entropy":
        emb_dim = int(np.prod(M.layer_shapes(spec)[-1]))
        head = {
            "w": jnp.asarray(
                rng.standard_normal((emb_dim, spec.n_classes)).astype(np.float32)
                * np.sqrt(1.0 / emb_dim)
            ),
            "b": jnp.zeros(spec.n_classes, dtype=jnp.float32),
        }
        state = (params, head)
        loss_fn = jax.jit(lambda s, x, y: _ce_loss(s, spec, x, y))
        grad_fn = jax.jit(jax.value_and_grad(lambda s, x, y: _ce_loss(s, spec, x, y)))
        m, v = _adam_init(state)
        history: List[float] = []
        for t in range(1, cfg.steps + 1):
            idx = rng.integers(0, len(train_x), size=cfg.batch)
            bx = jnp.asarray(train_x[idx])
            by = jnp.asarray(train_y[idx].astype(np.int32))
            loss, grads = grad_fn(state, bx, by)
            state, m, v = _adam_step(state, grads, m, v, t, cfg.lr)
            history.append(float(loss))
        params = state[0]
        return [
            {k: np.asarray(vv) for k, vv in p.items()} for p in params
        ], history

    if cfg.loss == "contrastive":
        coeffs = tuple(0.0 for _ in spec.layers[:-1]) + (1.0,)
    else:
        # Depth-increasing coefficients (a_i ∝ i+1): the paper tunes a_i by
        # exhaustive search and deeper layers carry the final accuracy, so
        # they get the larger share; shallow layers still receive direct
        # metric supervision (the whole point of the layer-aware loss).
        if cfg.layer_coeffs is not None:
            coeffs = cfg.layer_coeffs
        else:
            raw = tuple(float(i + 1) for i in range(spec.n_layers))
            coeffs = tuple(c / sum(raw) for c in raw)
    assert abs(sum(coeffs) - 1.0) < 1e-6, "Eq. 4 requires convex coefficients"

    grad_fn = jax.jit(
        jax.value_and_grad(
            lambda p, a, b, yy: _siamese_loss(p, spec, a, b, yy, coeffs, cfg.margin)
        )
    )
    m, v = _adam_init(params)
    history = []
    for t in range(1, cfg.steps + 1):
        x1, x2, yy = _sample_pairs(rng, train_x, train_y, cfg.batch)
        loss, grads = grad_fn(params, jnp.asarray(x1), jnp.asarray(x2), jnp.asarray(yy))
        params, m, v = _adam_step(params, grads, m, v, t, cfg.lr)
        history.append(float(loss))
    return [{k: np.asarray(vv) for k, vv in p.items()} for p in params], history
