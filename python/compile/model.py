"""L2: the agile DNN — per-layer JAX forward functions calling the L1 kernels.

An *agile DNN* (paper §4.2) is a representation learner whose execution may
terminate after any layer; the output of every layer is flattened, feature-
selected, and classified by that layer's semi-supervised k-means classifier.
Consequently the model here is defined as a sequence of independently
lowerable *unit* functions rather than a single fused forward pass:

    unit_i : (activation_in, centroids_i) -> (activation_out, l1_distances)

which is exactly the granularity at which the Rust coordinator schedules
(one unit == one schedulable imprecise-computing module).

Architectures mirror the paper's Table 3 at reduced channel counts
(DESIGN.md §7): conv layers are 3x3 VALID + ReLU + 2x2 max-pool; FC layers
are matmul + bias (+ ReLU except the final embedding layer).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d as ck
from .kernels import l1dist as lk
from .kernels import ref

__all__ = ["LayerSpec", "NetSpec", "NETWORKS", "init_params", "layer_forward",
           "forward_all_layers", "unit_fn", "feature_vector", "layer_shapes"]


@dataclass(frozen=True)
class LayerSpec:
    """One agile-DNN layer (== one Zygarde unit's compute)."""

    kind: str  # "conv" | "fc"
    out: int  # Cout for conv, width for fc
    pool: bool = True  # conv only: 2x2/2 max-pool after ReLU
    relu: bool = True


@dataclass(frozen=True)
class NetSpec:
    """A full agile DNN for one dataset (Table 3 structure, scaled)."""

    name: str
    input_shape: Tuple[int, int, int]
    n_classes: int
    layers: Tuple[LayerSpec, ...]
    n_features: int = 64  # top-F selected features per layer (paper: <=150)

    @property
    def n_layers(self) -> int:
        return len(self.layers)


# Table 3, channel-scaled. Layer *structure* (CONV/FC sequence) matches.
NETWORKS: Dict[str, NetSpec] = {
    "mnist": NetSpec(
        "mnist", (16, 16, 1), 10,
        (LayerSpec("conv", 8), LayerSpec("conv", 16),
         LayerSpec("fc", 64), LayerSpec("fc", 32, relu=False)),
    ),
    "esc10": NetSpec(
        "esc10", (16, 16, 1), 10,
        (LayerSpec("conv", 8), LayerSpec("conv", 16, pool=False),
         LayerSpec("conv", 16, pool=False), LayerSpec("fc", 32, relu=False)),
    ),
    "cifar100": NetSpec(
        "cifar100", (16, 16, 3), 5,
        (LayerSpec("conv", 16), LayerSpec("conv", 32),
         LayerSpec("fc", 96), LayerSpec("fc", 48, relu=False)),
    ),
    "vww": NetSpec(
        "vww", (16, 16, 3), 2,
        (LayerSpec("conv", 8), LayerSpec("conv", 8, pool=False),
         LayerSpec("conv", 16, pool=False), LayerSpec("conv", 16, pool=False),
         LayerSpec("fc", 32, relu=False)),
    ),
    # Fig. 23 multi-task visual workload: sign (bigger) + shape (smaller).
    "sign": NetSpec(
        "sign", (16, 16, 3), 6,
        (LayerSpec("conv", 8), LayerSpec("conv", 16),
         LayerSpec("fc", 48), LayerSpec("fc", 24, relu=False)),
    ),
    "shape": NetSpec(
        "shape", (16, 16, 3), 4,
        (LayerSpec("conv", 4), LayerSpec("conv", 8),
         LayerSpec("fc", 24), LayerSpec("fc", 16, relu=False)),
    ),
}

KSIZE = 3  # all convs are 3x3 VALID


def layer_shapes(spec: NetSpec) -> List[Tuple[int, ...]]:
    """Activation shape *after* each layer (and pooling)."""
    shapes: List[Tuple[int, ...]] = []
    cur: Tuple[int, ...] = spec.input_shape
    for layer in spec.layers:
        if layer.kind == "conv":
            h, w, _ = cur
            oh, ow = h - KSIZE + 1, w - KSIZE + 1
            if layer.pool:
                oh, ow = oh // 2, ow // 2
            cur = (oh, ow, layer.out)
        else:
            cur = (layer.out,)
        shapes.append(cur)
    return shapes


def init_params(spec: NetSpec, seed: int = 0) -> List[Dict[str, np.ndarray]]:
    """He-initialized parameters, one dict per layer: {"w": ..., "b": ...}."""
    rng = np.random.default_rng(seed)
    params: List[Dict[str, np.ndarray]] = []
    cur = spec.input_shape
    for layer in spec.layers:
        if layer.kind == "conv":
            cin = cur[2]
            fan_in = KSIZE * KSIZE * cin
            w = rng.standard_normal((KSIZE, KSIZE, cin, layer.out)) * np.sqrt(2.0 / fan_in)
            h, ww, _ = cur
            oh, ow = h - KSIZE + 1, ww - KSIZE + 1
            if layer.pool:
                oh, ow = oh // 2, ow // 2
            cur = (oh, ow, layer.out)
        else:
            fan_in = int(np.prod(cur))
            w = rng.standard_normal((fan_in, layer.out)) * np.sqrt(2.0 / fan_in)
            cur = (layer.out,)
        params.append({
            "w": w.astype(np.float32),
            "b": np.zeros(layer.out, dtype=np.float32),
        })
    return params


def layer_forward(layer: LayerSpec, p, x, use_pallas: bool = False):
    """Run one layer. `x` is the previous activation (3-D for conv, any for fc)."""
    if layer.kind == "conv":
        out = ck.conv2d(x, p["w"], p["b"], use_pallas=use_pallas)
        if layer.relu:
            out = jax.nn.relu(out)
        if layer.pool:
            out = ref.maxpool2_ref(out)
        return out
    flat = x.reshape(-1)
    out = ck.matmul(flat[None, :], p["w"], use_pallas=use_pallas)[0] + p["b"]
    if layer.relu:
        out = jax.nn.relu(out)
    return out


def forward_all_layers(spec: NetSpec, params, x, use_pallas: bool = False):
    """All per-layer activations for input `x` (training / trace path)."""
    acts = []
    cur = x
    for layer, p in zip(spec.layers, params):
        cur = layer_forward(layer, p, cur, use_pallas=use_pallas)
        acts.append(cur)
    return acts


def feature_vector(act: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Flatten a layer activation and gather its selected top-F features."""
    return act.reshape(-1)[idx]


def unit_fn(spec: NetSpec, params, layer_idx: int, feat_idx: np.ndarray,
            use_pallas: bool = True):
    """Build the lowerable *unit* function for `layer_idx`.

    Returns `f(act_in, centroids) -> (act_out, dists)` with the layer's
    weights closed over as constants (they are immutable at runtime) and the
    centroids left as a parameter (they are *mutated* at runtime by the
    semi-supervised adaptation, so the Rust side feeds the current values).
    The L1-distance computation is the Pallas `l1dist` kernel, so the exit
    test lowers into the same HLO as the layer itself — one PJRT execute per
    unit, no host round-trip between layer and classifier.
    """
    layer = spec.layers[layer_idx]
    p = {"w": jnp.asarray(params[layer_idx]["w"]),
         "b": jnp.asarray(params[layer_idx]["b"])}
    idx = jnp.asarray(feat_idx, dtype=jnp.int32)

    def f(act_in, centroids):
        act_out = layer_forward(layer, p, act_in, use_pallas=use_pallas)
        feat = feature_vector(act_out, idx)
        dists = lk.l1dist(centroids, feat, use_pallas=use_pallas)
        return act_out, dists

    return f
