"""AOT path: binfmt round-trip, HLO text export, jax re-execution of the
lowered unit (the artifact the Rust runtime consumes)."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, binfmt, costs, datasets, kmeans, model as M, train as T


def test_binfmt_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((3, 4, 5)).astype(np.float32),
        "b": np.arange(7, dtype=np.int32),
        "scalar_ish": np.array([3.5], dtype=np.float32),
        "empty_name_ok": np.zeros((2, 2), np.float32),
    }
    p = str(tmp_path / "t.bin")
    binfmt.write_archive(p, tensors)
    back = binfmt.read_archive(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_binfmt_rejects_bad_magic(tmp_path):
    p = str(tmp_path / "bad.bin")
    with open(p, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        binfmt.read_archive(p)


def test_cost_model_magnitudes():
    for name in ("mnist", "esc10", "cifar100", "vww"):
        cm = costs.build_cost_model(M.NETWORKS[name])
        assert cm.total_time_ms == pytest.approx(
            costs.TARGET_TOTAL_MS[name], rel=0.15
        )
        assert cm.e_man_mj > 0
        for u in cm.units:
            assert u.n_fragments >= 1
            assert u.fragment_ms * u.n_fragments == pytest.approx(u.time_ms, rel=1e-6)
        # first conv dominates FC layers (paper: 2.6-3.6x other layers)
        assert cm.units[0].time_ms > cm.units[-1].time_ms


def test_unit_hlo_text_parses_back():
    """Lower unit 0 of the mnist net to HLO text and parse it back through
    the XLA text parser — the exact entry point the Rust runtime uses
    (`HloModuleProto::from_text_file`). Full execute-and-compare against
    the jnp oracle happens in the Rust integration test
    (`rust/tests/runtime_vs_native.rs`), which runs the real PJRT path."""
    from jax._src.lib import xla_client as xc

    spec = M.NETWORKS["mnist"]
    tx, ty, *_ = datasets.generate("mnist")
    params, _ = T.train(spec, tx, ty, T.TrainConfig(steps=30))
    clfs = kmeans.build_classifiers(spec, params, tx[:300], ty[:300])
    hlo = aot.lower_unit(spec, params, 0, clfs[0], spec.input_shape)
    assert "ENTRY" in hlo

    mod = xc._xla.hlo_module_from_text(hlo)
    text2 = mod.to_string()
    # the reparsed module preserves both parameters and the tuple root
    assert "parameter(0)" in text2 and "parameter(1)" in text2
    k, f = clfs[0].centroids.shape
    assert f"f32[{k},{f}]" in text2.replace(" ", "")
    # lowered with return_tuple=True -> root is a tuple of two arrays
    assert "tuple(" in text2


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", ".stamp")),
    reason="artifacts not built yet (run `make artifacts`)",
)
def test_built_artifacts_complete():
    import json

    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in aot.HLO_DATASETS:
        d = os.path.join(root, name)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        assert meta["n_layers"] == len(meta["layers"])
        tensors = binfmt.read_archive(os.path.join(d, "tensors.bin"))
        for li in range(meta["n_layers"]):
            assert os.path.exists(os.path.join(d, f"unit{li}.hlo.txt"))
            assert f"layer{li}_w" in tensors
            assert f"layer{li}_centroids" in tensors
        assert "test_x" in tensors and "test_y" in tensors
        assert len(tensors["test_x"]) == meta["n_test"]
