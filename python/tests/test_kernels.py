"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

A hypothesis-style seeded sweep over shapes (the image has no `hypothesis`
package, so we enumerate a randomized-but-deterministic shape grid and a
seeded value generator, which gives the same coverage reproducibly).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import conv2d as ck
from compile.kernels import l1dist as lk
from compile.kernels import ref

RNG = np.random.default_rng(1234)

# Randomized shape grid: awkward primes, tile multiples, degenerate dims.
MATMUL_SHAPES = [(1, 1, 1), (1, 7, 3), (5, 5, 5), (8, 128, 8), (13, 27, 10),
                 (64, 64, 64), (17, 19, 23), (128, 9, 130), (100, 150, 2),
                 (196, 72, 16), (2, 301, 2)]


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
def test_matmul_matches_ref(m, k, n):
    a = RNG.standard_normal((m, k)).astype(np.float32)
    b = RNG.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(ck.matmul(jnp.array(a), jnp.array(b)))
    want = np.asarray(ref.matmul_ref(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(4, 8, 4), (32, 16, 8)])
def test_matmul_extreme_values(m, k, n):
    # Large magnitudes + zeros: padding must stay exact.
    a = (RNG.standard_normal((m, k)) * 1e3).astype(np.float32)
    a[0, :] = 0.0
    b = (RNG.standard_normal((k, n)) * 1e-3).astype(np.float32)
    got = np.asarray(ck.matmul(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-3, atol=1e-3)
    assert np.all(got[0, :] == 0.0)


CONV_SHAPES = [(8, 8, 1, 4), (16, 16, 1, 8), (16, 16, 3, 16), (7, 9, 2, 5),
               (5, 5, 4, 3)]


@pytest.mark.parametrize("h,w,cin,cout", CONV_SHAPES)
def test_conv2d_matches_ref(h, w, cin, cout):
    x = RNG.standard_normal((h, w, cin)).astype(np.float32)
    wgt = RNG.standard_normal((3, 3, cin, cout)).astype(np.float32)
    b = RNG.standard_normal((cout,)).astype(np.float32)
    got = np.asarray(ck.conv2d(jnp.array(x), jnp.array(wgt), jnp.array(b)))
    want = np.asarray(ref.conv2d_ref(jnp.array(x), jnp.array(wgt), jnp.array(b)))
    assert got.shape == (h - 2, w - 2, cout)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_naive_cross_check():
    # Independent O(n^6) loop oracle — guards against a bug shared by the
    # kernel and its im2col-based ref.
    h, w, cin, cout = 6, 6, 2, 3
    x = RNG.standard_normal((h, w, cin)).astype(np.float32)
    wgt = RNG.standard_normal((3, 3, cin, cout)).astype(np.float32)
    b = np.zeros(cout, np.float32)
    want = np.zeros((h - 2, w - 2, cout), np.float32)
    for i in range(h - 2):
        for j in range(w - 2):
            for co in range(cout):
                want[i, j, co] = np.sum(x[i:i + 3, j:j + 3, :] * wgt[:, :, :, co])
    got = np.asarray(ck.conv2d(jnp.array(x), jnp.array(wgt), jnp.array(b)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


L1_SHAPES = [(1, 1), (2, 5), (10, 64), (5, 150), (16, 37), (10, 128), (3, 257)]


@pytest.mark.parametrize("k,f", L1_SHAPES)
def test_l1dist_matches_ref(k, f):
    c = RNG.standard_normal((k, f)).astype(np.float32)
    x = RNG.standard_normal((f,)).astype(np.float32)
    got = np.asarray(lk.l1dist(jnp.array(c), jnp.array(x)))
    want = np.asarray(ref.l1dist_ref(jnp.array(c), jnp.array(x)))
    assert got.shape == (k,)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_l1dist_properties():
    # Metric sanity: d(x, x) = 0; symmetry in the abs; non-negativity.
    c = RNG.standard_normal((4, 32)).astype(np.float32)
    d_self = np.asarray(lk.l1dist(jnp.array(c), jnp.array(c[2])))
    assert d_self[2] == pytest.approx(0.0, abs=1e-6)
    assert np.all(d_self >= 0.0)


def test_maxpool_ref():
    x = np.arange(16, dtype=np.float32).reshape(4, 4, 1)
    out = np.asarray(ref.maxpool2_ref(jnp.array(x)))
    np.testing.assert_array_equal(out[..., 0], [[5, 7], [13, 15]])
    # odd edge truncation
    x5 = RNG.standard_normal((5, 5, 2)).astype(np.float32)
    assert ref.maxpool2_ref(jnp.array(x5)).shape == (2, 2, 2)
