"""L2 model: shapes, pallas/jnp equivalence of whole units, training smoke."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import datasets, kmeans, model as M, train as T

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("name", list(M.NETWORKS))
def test_layer_shapes_consistent(name):
    spec = M.NETWORKS[name]
    params = M.init_params(spec)
    x = jnp.asarray(RNG.standard_normal(spec.input_shape).astype(np.float32))
    acts = M.forward_all_layers(spec, params, x)
    for act, shape in zip(acts, M.layer_shapes(spec)):
        assert tuple(act.shape) == tuple(shape)
    # final embedding is 1-D
    assert acts[-1].ndim == 1


@pytest.mark.parametrize("name", ["mnist", "vww"])
def test_unit_fn_pallas_equals_jnp(name):
    """The lowered unit (Pallas path) must equal the training path (jnp)."""
    spec = M.NETWORKS[name]
    params = M.init_params(spec, seed=3)
    shapes = M.layer_shapes(spec)
    for li in range(spec.n_layers):
        in_shape = spec.input_shape if li == 0 else shapes[li - 1]
        flat = int(np.prod(shapes[li]))
        fidx = np.sort(RNG.choice(flat, size=min(16, flat), replace=False)).astype(np.int32)
        cents = RNG.standard_normal((spec.n_classes, len(fidx))).astype(np.float32)
        act_in = jnp.asarray(RNG.standard_normal(in_shape).astype(np.float32))
        f_pl = M.unit_fn(spec, params, li, fidx, use_pallas=True)
        f_np = M.unit_fn(spec, params, li, fidx, use_pallas=False)
        a1, d1 = f_pl(act_in, jnp.asarray(cents))
        a2, d2 = f_np(act_in, jnp.asarray(cents))
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)


def test_dataset_determinism_and_shapes():
    a = datasets.generate("mnist")
    b = datasets.generate("mnist")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    tx, ty, sx, sy, sd = a
    spec = datasets.DATASETS["mnist"]
    assert tx.shape == (spec.n_train, 16, 16, 1)
    assert sx.shape == (spec.n_test, 16, 16, 1)
    assert set(np.unique(ty)) <= set(range(spec.n_classes))
    assert np.all((sd >= 0) & (sd <= 1))


def test_environment_shift_identity_and_change():
    _, _, sx, _, _ = datasets.generate("esc10")
    assert datasets.environment_shift(sx, 0) is sx
    e1 = datasets.environment_shift(sx, 1)
    e2 = datasets.environment_shift(sx, 2)
    assert e1.shape == sx.shape
    # environments differ from the original and from each other
    assert np.abs(e1 - sx).mean() > 0.05
    assert np.abs(e2 - e1).mean() > 0.05


def test_training_reduces_loss():
    spec = M.NETWORKS["mnist"]
    tx, ty, *_ = datasets.generate("mnist")
    _, hist = T.train(spec, tx, ty, T.TrainConfig(steps=60, seed=1))
    assert np.mean(hist[-10:]) < np.mean(hist[:10]) * 0.8


def test_cross_entropy_training_runs():
    spec = M.NETWORKS["mnist"]
    tx, ty, *_ = datasets.generate("mnist")
    params, hist = T.train(spec, tx, ty,
                           T.TrainConfig(loss="cross_entropy", steps=40))
    assert len(params) == spec.n_layers
    assert np.isfinite(hist).all()


def test_pair_sampling_balance():
    rng = np.random.default_rng(0)
    x = RNG.standard_normal((100, 4)).astype(np.float32)
    y = np.repeat(np.arange(5), 20).astype(np.int32)
    x1, x2, yy = T._sample_pairs(rng, x, y, 64)
    assert yy.mean() == pytest.approx(0.5, abs=0.01)


def test_kmeans_classifier_construction():
    spec = M.NETWORKS["mnist"]
    tx, ty, sx, sy, _ = datasets.generate("mnist")
    params, _ = T.train(spec, tx, ty, T.TrainConfig(steps=120))
    clfs = kmeans.build_classifiers(spec, params, tx, ty)
    assert len(clfs) == spec.n_layers
    shapes = M.layer_shapes(spec)
    for clf, shape in zip(clfs, shapes):
        k, f = clf.centroids.shape
        assert k == spec.n_classes
        assert f <= spec.n_features
        assert np.all(clf.feat_idx < np.prod(shape))
        assert np.all(np.diff(clf.feat_idx) > 0)  # sorted, unique
        assert clf.threshold >= 0.0
        assert len(clf.curve) > 0
        # curve exit-rate must be monotonically non-increasing in threshold
        rates = [r for _, r, _ in clf.curve]
        assert all(a >= b - 1e-9 for a, b in zip(rates, rates[1:]))
        # classifier must beat chance on its own training data
        feats = kmeans.collect_features(spec, params, tx[:200])[0]
        pred, _ = kmeans._classify(clf.centroids, clf.centroid_label,
                                   feats[:, clf.feat_idx]) if clf is clfs[0] else (None, None)
        if pred is not None:
            assert (pred == ty[:200]).mean() > 1.5 / spec.n_classes
