"""Regression tests for the HLO-text export path (aot.to_hlo_text).

The nastiest failure mode we hit building this repo: XLA's default HLO
printer ELIDES large constants (`constant({...})`), and the 0.5.1 text
parser silently reads the elision back as zeros — the trained weights
vanish from the artifact while everything still "works" (outputs become
bias-only and input-independent). These tests pin the fix.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot


def _lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def test_large_constants_are_printed():
    w = np.arange(72.0, dtype=np.float32).reshape(9, 8) * 1.5
    wj = jnp.asarray(w)

    def f(x):
        return (x @ wj,)

    text = aot.to_hlo_text(_lower(f, jax.ShapeDtypeStruct((4, 9), jnp.float32)))
    # The elided form must not appear, and a distinctive weight value must.
    assert "constant({...})" not in text
    assert "106.5" in text  # 71 * 1.5


def test_metadata_stripped():
    def f(x):
        return (x * 2.0,)

    text = aot.to_hlo_text(_lower(f, jax.ShapeDtypeStruct((4,), jnp.float32)))
    # jax>=0.8 metadata attrs break the xla_extension 0.5.1 parser.
    assert "source_end_line" not in text
    assert "metadata=" not in text


def test_exported_text_reparses():
    from jax._src.lib import xla_client as xc

    w = jnp.asarray(np.random.default_rng(0).standard_normal((20, 12)), dtype=jnp.float32)

    def f(x):
        return (jax.nn.relu(x @ w),)

    text = aot.to_hlo_text(_lower(f, jax.ShapeDtypeStruct((3, 20), jnp.float32)))
    mod = xc._xla.hlo_module_from_text(text)  # must not raise
    assert "parameter(0)" in mod.to_string()


def test_output_is_input_dependent_after_roundtrip():
    """End-to-end guard: lower -> text -> parse -> the weights survive.

    We verify by checking that a distinctive trained-weight value is
    present in the REPARSED module text (not just the printed one).
    """
    from jax._src.lib import xla_client as xc

    w = np.full((10, 4), 7.125, dtype=np.float32)
    w[3, 2] = -123.456
    wj = jnp.asarray(w)

    def f(x):
        return (x @ wj,)

    from jaxlib import _jax

    text = aot.to_hlo_text(_lower(f, jax.ShapeDtypeStruct((2, 10), jnp.float32)))
    opts = _jax.HloPrintOptions()
    opts.print_large_constants = True  # default printing would elide again
    reparsed = xc._xla.hlo_module_from_text(text).to_string(opts)
    assert "-123.456" in reparsed, "weights lost in text round-trip"


@pytest.mark.parametrize("ds", ["mnist", "vww"])
def test_built_unit_hlo_contains_weights(ds):
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        ds, "unit0.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    text = open(path).read()
    assert "constant({...})" not in text, "artifact has elided constants"
    # unit0 bakes a (3,3,cin,cout) conv kernel: a large f32 constant exists.
    assert text.count("constant(") >= 2
