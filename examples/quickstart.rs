//! Quickstart: the minimal Zygarde serving loop.
//!
//! Loads the MNIST agile DNN's AOT-compiled per-unit HLO artifacts
//! (`make artifacts` must have run), executes them unit-by-unit through
//! the XLA PJRT runtime with the utility-test early exit, and adapts the
//! k-means centroids online — the full three-layer stack with Python
//! nowhere on the path.
//!
//!     cargo run --release --example quickstart -- [--dataset mnist] [--samples 40]

use zygarde::dnn::network::Network;
use zygarde::runtime::Runtime;
use zygarde::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let ds = args.str_or("dataset", "mnist").to_string();
    let n_samples = args.usize_or("samples", 40);

    let dir = zygarde::artifacts_root().join(&ds);
    let mut net = Network::load(&dir).expect("artifacts — run `make artifacts` first");
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("quickstart needs the PJRT serving path: {e}");
            std::process::exit(1);
        }
    };
    rt.load_network(&dir, &net.meta).expect("loading AOT units");
    println!(
        "zygarde quickstart: `{ds}` ({} units) on {} — utility thresholds {:?}",
        net.meta.n_layers,
        rt.platform(),
        net.meta.layers.iter().map(|l| l.threshold).collect::<Vec<_>>()
    );

    let n = n_samples.min(net.test.len());
    let mut correct = 0usize;
    let mut exits = vec![0usize; net.meta.n_layers];
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let mut act = net.test.sample(i).to_vec();
        let (mut pred, mut exit_at) = (0i32, net.meta.n_layers - 1);
        for li in 0..net.meta.n_layers {
            let (next, dists) = rt
                .execute_unit(&ds, li, &act, &net.classifiers[li].centroids)
                .expect("unit execution");
            let res = net.classifiers[li].classify_from_dists(&dists);
            pred = res.pred;
            if res.exit || li == net.meta.n_layers - 1 {
                exit_at = li;
                // Online semi-supervised adaptation on confident exits.
                if res.exit {
                    let mut feat = Vec::new();
                    net.classifiers[li].gather(&next, &mut feat);
                    let feat = feat.clone();
                    net.classifiers[li].adapt(res.best, &feat);
                }
                break;
            }
            act = next;
        }
        exits[exit_at] += 1;
        let ok = pred == net.test.y[i];
        correct += ok as usize;
        if i < 10 {
            println!(
                "  sample {i:>3}: label {} -> pred {pred} ({}) exited after unit {}",
                net.test.y[i],
                if ok { "ok" } else { "WRONG" },
                exit_at + 1
            );
        }
    }
    println!(
        "\n{n} samples  accuracy {:.1}%  mean PJRT latency {:.2} ms  exit histogram {exits:?}",
        100.0 * correct as f64 / n as f64,
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    );
}
