//! Quickstart: the minimal Zygarde loop — works out of the box.
//!
//! With AOT artifacts (`make artifacts`) and the `pjrt` feature, this is
//! the serving path: the MNIST agile DNN's per-unit HLO executed through
//! the XLA PJRT runtime with the utility-test early exit and online
//! k-means adaptation — Python nowhere on the path.
//!
//! Without them (the default build), it falls back to the simulation
//! stack: a small deterministic scenario sweep over schedulers and NVM
//! commit policies on the synthetic workload, which needs no artifacts
//! and no external crates.
//!
//!     cargo run --release --example quickstart -- [--dataset mnist] [--samples 40]

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::dnn::network::Network;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::nvm::NvmSpec;
use zygarde::runtime::Runtime;
use zygarde::sim::sweep::{self, HarvesterSpec, ScenarioMatrix, SeedPolicy, TaskMix};
use zygarde::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let ds = args.str_or("dataset", "mnist").to_string();
    let n_samples = args.usize_or("samples", 40);
    let seed = args.u64_or("seed", 7);

    let dir = zygarde::artifacts_root().join(&ds);
    match (Network::load(&dir), Runtime::cpu()) {
        (Ok(net), Ok(rt)) => serve_quickstart(net, rt, &dir, &ds, n_samples),
        (net, rt) => {
            if let Err(e) = net {
                eprintln!("artifacts unavailable ({e}); run `make artifacts` for the serving path");
            }
            if let Err(e) = rt {
                eprintln!("PJRT unavailable ({e})");
            }
            sim_quickstart(seed);
        }
    }
}

/// The PJRT serving path (artifacts + `--features pjrt` present).
fn serve_quickstart(
    mut net: Network,
    mut rt: Runtime,
    dir: &std::path::Path,
    ds: &str,
    n_samples: usize,
) {
    rt.load_network(dir, &net.meta).expect("loading AOT units");
    println!(
        "zygarde quickstart: `{ds}` ({} units) on {} — utility thresholds {:?}",
        net.meta.n_layers,
        rt.platform(),
        net.meta.layers.iter().map(|l| l.threshold).collect::<Vec<_>>()
    );

    let n = n_samples.min(net.test.len());
    let mut correct = 0usize;
    let mut exits = vec![0usize; net.meta.n_layers];
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let mut act = net.test.sample(i).to_vec();
        let (mut pred, mut exit_at) = (0i32, net.meta.n_layers - 1);
        for li in 0..net.meta.n_layers {
            let (next, dists) = rt
                .execute_unit(ds, li, &act, &net.classifiers[li].centroids)
                .expect("unit execution");
            let res = net.classifiers[li].classify_from_dists(&dists);
            pred = res.pred;
            if res.exit || li == net.meta.n_layers - 1 {
                exit_at = li;
                // Online semi-supervised adaptation on confident exits.
                if res.exit {
                    let mut feat = Vec::new();
                    net.classifiers[li].gather(&next, &mut feat);
                    let feat = feat.clone();
                    net.classifiers[li].adapt(res.best, &feat);
                }
                break;
            }
            act = next;
        }
        exits[exit_at] += 1;
        let ok = pred == net.test.y[i];
        correct += ok as usize;
        if i < 10 {
            println!(
                "  sample {i:>3}: label {} -> pred {pred} ({}) exited after unit {}",
                net.test.y[i],
                if ok { "ok" } else { "WRONG" },
                exit_at + 1
            );
        }
    }
    println!(
        "\n{n} samples  accuracy {:.1}%  mean PJRT latency {:.2} ms  exit histogram {exits:?}",
        100.0 * correct as f64 / n as f64,
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    );
}

/// The default-build path: a deterministic sweep on the synthetic
/// workload — schedulers × NVM commit policies on paired seeds.
fn sim_quickstart(seed: u64) {
    println!(
        "\nrunning the simulation quickstart instead: Zygarde vs EDF-M on a \
         synthetic 2-task mix,\nacross NVM commit policies (ideal, FRAM \
         every-fragment, FRAM JIT), paired harvest streams\n"
    );
    let matrix = ScenarioMatrix::new("quickstart", seed)
        .mixes(vec![TaskMix::synthetic("demo", 2, 3, seed)])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 90.0,
                q: 0.85,
                duty: 0.55,
                eta: 0.45,
            },
        ])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::EdfMandatory])
        .nvms(vec![
            NvmSpec::ideal(),
            NvmSpec::fram_every_fragment(),
            NvmSpec::fram_jit(),
        ])
        .duration_ms(20_000.0)
        .seed_policy(SeedPolicy::PairedEnvironment);
    let report = sweep::run_matrix(&matrix, sweep::default_threads());
    report.print();
    println!(
        "\ncommits {}  restores {}  lost fragments {}  commit energy {:.2} mJ \
         (see `zygarde nvm` for the full policy comparison)",
        report.summary.commits,
        report.summary.restores,
        report.summary.lost_fragments,
        report.summary.commit_mj
    );
}
