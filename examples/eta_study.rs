//! Harvester characterization study (paper §3): generate two-month
//! equivalent energy-event traces for four harvester types, estimate each
//! one's conditional-event distribution h(N) and η-factor, validate η
//! against the measured next-slot prediction accuracy (Fig. 25), and show
//! the calibration loop used by the controlled experiments (binary-search
//! a Markov burst process to a target η).
//!
//!     cargo run --release --example eta_study -- [--target 0.71] [--seed 7]

use zygarde::energy::harvester::{calibrate_markov, HarvesterKind};
use zygarde::exp::eta;
use zygarde::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let seed = args.u64_or("seed", 7);
    let target = args.f64_or("target", 0.71);

    let studies = eta::run(20, seed);
    eta::print_figure4(&studies);
    eta::print_figure25(&studies);

    println!("\n== calibration: Markov burst process -> target η = {target} ==");
    for (kind, power, duty) in [
        (HarvesterKind::Solar, 600.0, 0.6),
        (HarvesterKind::Rf, 70.0, 0.6),
    ] {
        let (q, achieved) = calibrate_markov(kind, power / duty, duty, target, seed);
        println!(
            "{:?}: stay-probability q = {q:.4} gives η = {achieved:.3}",
            kind
        );
    }
    println!(
        "\nschedulability note: E[outage] = η/(1−η) = {:.2} energy events at η = {target}",
        target / (1.0 - target)
    );
}
