//! Multi-task visual sensing (paper §9.2, Fig. 23): traffic-sign + shape
//! recognition sharing one solar-harvested device and one camera. Compares
//! Zygarde against the SONIC-EDF and SONIC-RR baselines and prints the
//! fairness breakdown per task.
//!
//!     cargo run --release --example visual_multitask -- [--minutes 10] [--seed 7]

use zygarde::exp::visual;
use zygarde::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let minutes = args.f64_or("minutes", 10.0);
    let seed = args.u64_or("seed", 7);

    println!(
        "visual multitask: sign + shape recognizers, solar (η=0.38), camera {} mJ/frame",
        visual::CAMERA_ENERGY_MJ
    );
    let cells = visual::run(minutes * 60_000.0, seed);
    visual::print(&cells);

    // Narrative summary, Fig. 23-style.
    for c in &cells {
        let m = &c.metrics;
        let name = match c.scheduler {
            zygarde::coordinator::sched::SchedulerKind::Zygarde => "zygarde",
            zygarde::coordinator::sched::SchedulerKind::Edf => "sonic-edf",
            _ => "sonic-rr",
        };
        let sign = m.per_task_scheduled[0] as f64 / m.per_task_released[0].max(1) as f64;
        let shape = m.per_task_scheduled[1] as f64 / m.per_task_released[1].max(1) as f64;
        println!(
            "{name:<10} schedules {:>5.1}% of entering jobs  (sign {:>5.1}%, shape {:>5.1}%)",
            100.0 * m.scheduled_rate(),
            100.0 * sign,
            100.0 * shape
        );
    }
}
