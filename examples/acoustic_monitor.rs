//! Acoustic event monitor: the paper's §9.1 deployment as a runnable app.
//!
//! A batteryless audio event detector (ESC-10 agile DNN) on a chosen
//! harvester, scheduled by Zygarde under intermittent power. Prints the
//! live voltage trace, per-event outcomes, and the Fig. 22-style summary.
//!
//!     cargo run --release --example acoustic_monitor -- \
//!         [--app car-detector|dog-monitor|people-detector|baby-monitor|laundry-monitor|printer-monitor] \
//!         [--minutes 10] [--seed 7]

use zygarde::exp::acoustic;
use zygarde::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let minutes = args.f64_or("minutes", 10.0);
    let seed = args.u64_or("seed", 7);
    let which = args.opt_str("app").map(str::to_string);

    let results = acoustic::run(minutes * 60_000.0, seed);
    let selected: Vec<_> = results
        .iter()
        .filter(|r| which.as_deref().map(|w| w == r.app).unwrap_or(true))
        .collect();
    if selected.is_empty() {
        eprintln!(
            "unknown --app; choose one of: {}",
            acoustic::APPS.iter().map(|a| a.name).collect::<Vec<_>>().join(", ")
        );
        std::process::exit(2);
    }

    for r in &selected {
        let m = &r.metrics;
        println!("\n=== {} ({} min) ===", r.app, minutes);
        println!(
            "events {:>4}   captured {:>4}   scheduled {:>4} ({:.1}%)   correct {:>4} ({:.1}% of scheduled)",
            m.released + m.capture_missed,
            m.released,
            m.scheduled,
            100.0 * m.event_scheduled_rate(),
            m.correct,
            100.0 * m.accuracy()
        );
        println!(
            "deadline misses {}   capture misses {}   reboots {}   re-executed fragments {}   on-time {:.1}%",
            m.deadline_missed, m.capture_missed, m.reboots, m.refragments,
            100.0 * m.on_fraction()
        );
        // Voltage sparkline (one char ≈ 10 s at default sampling).
        let marks: String = r
            .voltage
            .iter()
            .step_by((r.voltage.len() / 72).max(1))
            .map(|&(_, v)| {
                let lvl = ((v / 3.3) * 7.0).clamp(0.0, 7.0) as usize;
                ['.', ':', '-', '=', '+', '*', '#', '@'][lvl]
            })
            .collect();
        println!("V(t) {marks}");
    }
}
