//! Inference-path benchmarks: the per-unit hot path both natively (the
//! trace precomputation / simulation path) and through PJRT (the serving
//! path executing the AOT Pallas-bearing HLO), plus the k-means classify
//! and centroid-adaptation micro-costs the paper's Fig. 14 reasons about.

use zygarde::dnn::kmeans::Scratch;
use zygarde::dnn::network::Network;
use zygarde::dnn::trace::compute_traces;
use zygarde::runtime::Runtime;
use zygarde::util::bench::Bencher;

fn main() {
    let b = Bencher::default();
    let root = zygarde::artifacts_root();
    if !root.join("mnist/meta.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    for ds in ["mnist", "esc10", "cifar100", "vww"] {
        let net = Network::load(&root.join(ds)).unwrap();
        let mut scratch = Scratch::default();
        let sample = net.test.sample(0).to_vec();

        // Native per-unit forward (unit 0 = the heaviest conv).
        b.run(&format!("native/{ds}/unit0"), || {
            net.run_unit_native(0, &sample, &mut scratch).1.pred
        })
        .report();

        // Native whole-network early-exit inference.
        b.run(&format!("native/{ds}/infer"), || {
            net.infer_native(&sample, &mut scratch)
        })
        .report();

        // k-means classify on the final embedding (the multiplication-free
        // exit test — paper: 14x cheaper than the DNN).
        let flat = net.meta.flat_dim(net.meta.n_layers - 1);
        let act = vec![0.25f32; flat];
        let li = net.meta.n_layers - 1;
        b.run_throughput(
            &format!("classify/{ds}/k{}xF{}", net.classifiers[li].k, net.classifiers[li].n_features),
            (net.classifiers[li].k * net.classifiers[li].n_features) as f64,
            "dist-ops/s",
            || net.classifiers[li].classify(&act, &mut scratch).pred,
        )
        .report();

        // Trace precomputation over the whole test set (what the scheduler
        // sweeps amortize).
        b.run_throughput(
            &format!("traces/{ds}/{}samples", net.test.len()),
            net.test.len() as f64,
            "samples/s",
            || compute_traces(&net, None).len(),
        )
        .report();
    }

    // PJRT serving path (mnist): per-unit execute and full early-exit
    // inference through the AOT artifacts. Skipped when the crate is built
    // without the `pjrt` feature (the stub runtime reports unavailable).
    let ds = "mnist";
    let net = Network::load(&root.join(ds)).unwrap();
    match Runtime::cpu() {
        Err(e) => eprintln!("skipping PJRT benches: {e}"),
        Ok(mut rt) => {
            rt.load_network(&root.join(ds), &net.meta).unwrap();
            let sample = net.test.sample(0).to_vec();
            b.run(&format!("pjrt/{ds}/unit0"), || {
                rt.execute_unit(ds, 0, &sample, &net.classifiers[0].centroids).unwrap().1[0]
            })
            .report();
            b.run(&format!("pjrt/{ds}/infer-early-exit"), || {
                let mut act = sample.clone();
                let mut pred = 0;
                for li in 0..net.meta.n_layers {
                    let (next, dists) = rt
                        .execute_unit(ds, li, &act, &net.classifiers[li].centroids)
                        .unwrap();
                    let res = net.classifiers[li].classify_from_dists(&dists);
                    pred = res.pred;
                    if res.exit {
                        break;
                    }
                    act = next;
                }
                pred
            })
            .report();
        }
    }

    // Centroid adaptation (runtime update + deep propagation).
    let mut net2 = Network::load(&root.join(ds)).unwrap();
    let feat = vec![0.5f32; net2.classifiers[0].n_features];
    b.run("adapt/mnist/centroid-update", || {
        net2.classifiers[0].adapt(0, &feat);
    })
    .report();
    b.run("adapt/mnist/deep-propagation", || {
        zygarde::dnn::adapt::propagate_centroid(&mut net2, 0, 0);
    })
    .report();
}
