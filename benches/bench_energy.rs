//! Energy-substrate benchmarks: harvester stepping, capacitor charge/draw,
//! η estimation (the offline characterization cost), and the capacitor-
//! sweep / CHRT experiments at bench scale (Fig. 21 / Table 5 shape).

use zygarde::energy::capacitor::Capacitor;
use zygarde::energy::events::eta_factor;
use zygarde::energy::harvester::{Harvester, HarvesterKind};
use zygarde::exp::{capacitor_sweep, chrt_cmp};
use zygarde::util::bench::Bencher;

fn main() {
    let b = Bencher::default();

    let mut h = Harvester::markov(HarvesterKind::Rf, 80.0, 0.9, 0.6, 1000.0, 3);
    b.run_throughput("harvester/markov-step", 1.0, "steps/s", || h.step(7.5))
        .report();

    let mut cap = Capacitor::standard();
    cap.precharge();
    b.run_throughput("capacitor/charge+draw", 1.0, "ops/s", || {
        cap.charge(80.0, 7.5);
        cap.draw(0.6)
    })
    .report();

    // η estimation over a 30k-window trace (the calibration inner loop).
    let trace = {
        let mut h = Harvester::markov(HarvesterKind::Solar, 500.0, 0.92, 0.6, 1000.0, 9);
        h.event_trace(30_000, 150.0)
    };
    b.run(&format!("eta/estimate ({} windows)", trace.len()), || {
        eta_factor(&trace, 20, 0).eta
    })
    .report();

    if !zygarde::artifacts_root().join("cifar100/meta.json").exists() {
        eprintln!("artifacts missing — skipping experiment benches");
        return;
    }

    // Fig. 21 at bench scale: per-capacitor simulated-seconds throughput.
    let t0 = std::time::Instant::now();
    let cells = capacitor_sweep::run(30, 5);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "bench fig21/capacitor-sweep: 4 sizes x 30 jobs in {dt:.2}s — rates {:?}",
        cells
            .iter()
            .map(|c| format!("{}mF={:.2}", c.c_mf, c.metrics.event_scheduled_rate()))
            .collect::<Vec<_>>()
    );

    // Table 5 at bench scale.
    let t0 = std::time::Instant::now();
    let rows = chrt_cmp::run(150, 5);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "bench table5/chrt: 3 systems x 2 clocks x 150 jobs in {dt:.2}s — losses {:?}",
        rows.iter()
            .map(|r| format!(
                "S{}:{:+}",
                r.system_id,
                r.scheduled_rtc as i64 - r.scheduled_chrt as i64
            ))
            .collect::<Vec<_>>()
    );
}
