//! Sweep-engine throughput: scenarios/sec at 1, 2, 4, and 8 threads over
//! a synthetic 96-scenario matrix (no artifacts needed), cross-checking
//! that every thread count produces the byte-identical report.
//!
//! Run with `cargo bench --bench bench_sweep`. Scale the workload with
//! SWEEP_BENCH_REPS (default 4 reps → 96 scenarios) and
//! SWEEP_BENCH_DURATION_MS (default 20000 ms of simulated time per cell).

use std::time::Instant;

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::sim::sweep::{run_matrix, FaultPlan, HarvesterSpec, ScenarioMatrix, TaskMix};

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let reps = env_u64("SWEEP_BENCH_REPS", 4);
    let duration_ms = env_u64("SWEEP_BENCH_DURATION_MS", 20_000) as f64;

    // 2 harvesters × 1 cap × 3 schedulers × 2 faults × reps → 12·reps
    // scenarios, plus a second mix doubling it: 24·reps (96 at default).
    let matrix = ScenarioMatrix::new("bench-sweep", 0xB5EE9)
        .mixes(vec![
            TaskMix::synthetic("uni", 1, 3, 11),
            TaskMix::synthetic("duo", 2, 3, 12),
        ])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 120.0,
                q: 0.9,
                duty: 0.6,
                eta: 0.51,
            },
        ])
        .schedulers(vec![
            SchedulerKind::Zygarde,
            SchedulerKind::EdfMandatory,
            SchedulerKind::Edf,
        ])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none().with_brownouts(2_000.0, 400.0, 250.0),
        ])
        .reps(reps)
        .duration_ms(duration_ms);

    let n = matrix.len();
    println!("bench-sweep: {n} scenarios × {duration_ms} ms simulated each\n");

    let mut runs: Vec<(usize, f64, String)> = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let report = run_matrix(&matrix, threads);
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        let speedup = rate / runs.first().map(|(_, r1, _)| *r1).unwrap_or(rate);
        println!(
            "threads {threads}: {:>8.1} scenarios/s  ({dt:.3} s total, {speedup:.2}x vs 1 thread)",
            rate
        );
        runs.push((threads, rate, report.json_string()));
    }
    let reference = &runs[0].2;
    for (threads, _, json) in &runs[1..] {
        assert_eq!(
            reference, json,
            "thread count {threads} changed the report — determinism broken"
        );
    }
}
