//! Sweep-engine throughput: scenarios/sec at 1, 2, 4, and 8 threads over
//! a synthetic 96-scenario matrix (no artifacts needed), cross-checking
//! that every thread count produces the byte-identical report; a
//! per-NVM-commit-policy section (the commit path is on the engine's hot
//! loop); and a sharded-execution section that spawns N single-threaded
//! `zygarde sweep --shard i/N` processes, merges their PartialReports,
//! and cross-checks the merge against the in-process reference — the
//! N-processes-vs-N-threads comparison the scale-out story rests on.
//! A streaming-dispatcher section (`zygarde serve --workers N` over
//! pipes, byte-checked against the same reference) tracks the
//! work-stealing path next to the static shard rows it supersedes.
//!
//! Run with `cargo bench --bench bench_sweep`. Scale the workload with
//! SWEEP_BENCH_REPS (default 4 reps → 96 scenarios) and
//! SWEEP_BENCH_DURATION_MS (default 20000 ms of simulated time per cell).
//!
//! Emits a machine-readable `BENCH_sweep.json` (path overridable via
//! SWEEP_BENCH_JSON) so the perf trajectory is tracked across PRs;
//! `tools/bench_gate.py` diffs it against the committed
//! `BENCH_baseline.json` in CI and fails on a >30 % throughput drop.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::process::Command;
use std::rc::Rc;
use std::time::Instant;

use zygarde::clock::{ChrtTier, ClockSpec};
use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::exp::sweep_cli::bench_matrix;
use zygarde::nvm::NvmSpec;
use zygarde::sim::sweep::{
    merge, run_matrix, run_matrix_reference, run_scenario, run_scenario_profiled,
    run_scenario_with_sink, CellResult, FaultPlan, HarvesterSpec, PartialReport, ScenarioMatrix,
    SweepReport, TaskMix,
};
use zygarde::sim::workload::synthetic_task;
use zygarde::telemetry::registry::{Counter, Registry};
use zygarde::telemetry::CountingSink;
use zygarde::util::json::Value;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

fn main() {
    let reps = env_u64("SWEEP_BENCH_REPS", 4);
    let duration_ms = env_u64("SWEEP_BENCH_DURATION_MS", 20_000) as f64;

    // The shared bench grid (exp::sweep_cli::bench_matrix): 2 mixes ×
    // 2 harvesters × 3 schedulers × 2 faults × reps → 24·reps scenarios
    // (96 at default). Shared with the CLI so the sharded rows below run
    // the exact same matrix in child processes.
    let matrix = bench_matrix(reps, duration_ms);

    let n = matrix.len();
    println!("bench-sweep: {n} scenarios × {duration_ms} ms simulated each\n");

    let mut runs: Vec<(usize, f64, f64, String)> = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let report = run_matrix(&matrix, threads);
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        let speedup = rate / runs.first().map(|(_, r1, _, _)| *r1).unwrap_or(rate);
        println!(
            "threads {threads}: {:>8.1} scenarios/s  ({dt:.3} s total, {speedup:.2}x vs 1 thread)",
            rate
        );
        runs.push((threads, rate, dt, report.json_string()));
    }
    let reference = runs[0].3.clone();
    for (threads, _, _, json) in &runs[1..] {
        assert_eq!(
            &reference, json,
            "thread count {threads} changed the report — determinism broken"
        );
    }

    // --- sharded execution: N single-threaded processes vs N threads ----
    // Spawns the real CLI (`zygarde sweep --matrix bench --shard i/N`), so
    // the measured rate includes process startup, matrix expansion, and
    // shard-file serialization — the true cross-host orchestration cost.
    println!();
    let exe = env!("CARGO_BIN_EXE_zygarde");
    let pid = std::process::id();
    let mut shard_rows: Vec<(usize, f64, f64)> = Vec::new();
    for &procs in &[2usize, 4] {
        let paths: Vec<std::path::PathBuf> = (0..procs)
            .map(|i| {
                std::env::temp_dir().join(format!("zygarde_bench_{pid}_shard_{i}_of_{procs}.json"))
            })
            .collect();
        let t0 = Instant::now();
        let children: Vec<_> = (0..procs)
            .map(|i| {
                Command::new(exe)
                    .args([
                        "sweep",
                        "--matrix",
                        "bench",
                        "--reps",
                        &reps.to_string(),
                        "--duration-ms",
                        &duration_ms.to_string(),
                        "--shard",
                        &format!("{i}/{procs}"),
                        "--threads",
                        "1",
                        "--out",
                        paths[i].to_str().unwrap(),
                    ])
                    .stdout(std::process::Stdio::null())
                    .spawn()
                    .expect("spawning zygarde sweep shard process")
            })
            .collect();
        for mut c in children {
            let status = c.wait().expect("waiting for shard process");
            assert!(status.success(), "shard process failed: {status}");
        }
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        let threads_rate = runs
            .iter()
            .find(|(t, ..)| *t == procs)
            .map(|(_, r, ..)| *r)
            .unwrap_or(f64::NAN);
        println!(
            "shards  {procs}x1-thread procs: {rate:>8.1} scenarios/s  ({dt:.3} s, \
             {:.2}x of {procs}-thread in-process)",
            rate / threads_rate
        );

        // The merged shard files must reproduce the in-process report
        // byte-for-byte — the determinism contract, now across processes.
        let parts: Vec<PartialReport> = paths
            .iter()
            .map(|p| PartialReport::from_file(p).expect("reading shard report"))
            .collect();
        let merged = merge(&parts).expect("merging shard reports");
        assert_eq!(
            merged.json_string(),
            reference,
            "{procs}-process sharded run diverged from the in-process report"
        );
        for p in &paths {
            let _ = std::fs::remove_file(p);
        }
        shard_rows.push((procs, rate, dt));
    }

    // --- streaming dispatcher: serve/work over pipes ---------------------
    // Spawns the real `zygarde serve --workers N` (which itself spawns N
    // single-threaded `zygarde work --connect -` children), so the rate
    // includes process startup, the fingerprint handshake, lease
    // streaming, and the out-of-core merge. Cross-checked byte-identical
    // against the in-process reference, and printed next to the static
    // N-shard rows it supersedes.
    println!();
    let mut serve_rows: Vec<(usize, f64, f64)> = Vec::new();
    for &procs in &[1usize, 2, 4] {
        let out_path = std::env::temp_dir().join(format!("zygarde_bench_{pid}_serve_{procs}.json"));
        let t0 = Instant::now();
        let status = Command::new(exe)
            .args([
                "serve",
                "--matrix",
                "bench",
                "--reps",
                &reps.to_string(),
                "--duration-ms",
                &duration_ms.to_string(),
                "--workers",
                &procs.to_string(),
                "--worker-threads",
                "1",
                "--quiet",
                "true",
                "--out",
                out_path.to_str().unwrap(),
            ])
            .stdout(std::process::Stdio::null())
            .status()
            .expect("running zygarde serve");
        assert!(status.success(), "serve run failed: {status}");
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        match shard_rows.iter().find(|(p, ..)| *p == procs) {
            Some((_, static_rate, _)) => println!(
                "serve   {procs}x1-thread workers: {rate:>8.1} scenarios/s  ({dt:.3} s, \
                 {:.2}x of static {procs}-shard)",
                rate / static_rate
            ),
            None => println!(
                "serve   {procs}x1-thread workers: {rate:>8.1} scenarios/s  ({dt:.3} s)"
            ),
        }
        let served = std::fs::read_to_string(&out_path).expect("reading served report");
        assert_eq!(
            served, reference,
            "{procs}-worker dispatcher run diverged from the in-process report"
        );
        let _ = std::fs::remove_file(&out_path);
        serve_rows.push((procs, rate, dt));
    }

    // --- event-driven regime rows: fast-forward vs reference ------------
    // Each matrix concentrates simulated time in one engine regime. The
    // first three are dark-dominated (below the boot voltage with an empty
    // queue); `onidle-solar` idles powered-on between sparse releases
    // (`advance_on_phase_idle`); `rf-queued` keeps a job backlog queued
    // across off phases under a skewed CHRT clock, exercising the
    // believed-deadline watch in `advance_off_phase`. Each matrix runs on
    // the optimized engine AND the naive reference stepper, asserts the
    // reports are byte-identical (the CI differential proof on real
    // workloads), and reports the speedup; `tools/bench_gate.py` enforces
    // the committed per-row `min_speedup`.
    println!();
    let off_matrices: Vec<(&str, ScenarioMatrix)> = vec![
        (
            "rf-lowduty",
            ScenarioMatrix::new("off-rf-lowduty", 0x0FF1)
                .mixes(vec![TaskMix::synthetic("uni", 1, 3, 21)])
                .harvesters(vec![HarvesterSpec::Markov {
                    kind: HarvesterKind::Rf,
                    on_power_mw: 90.0,
                    q: 0.97,
                    duty: 0.12,
                    eta: 0.38,
                }])
                .capacitors_mf(vec![10.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .reps(2)
                // Long enough that the reference leg is well clear of
                // Instant/scheduler noise — the speedup floor gates on
                // this ratio unconditionally.
                .duration_ms(7_200_000.0),
        ),
        (
            "piezo",
            ScenarioMatrix::new("off-piezo", 0x0FF2)
                .mixes(vec![TaskMix::synthetic("uni", 1, 3, 22)])
                .harvesters(vec![HarvesterSpec::Piezo { eta: 0.3 }])
                .capacitors_mf(vec![50.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .duration_ms(14_400_000.0), // 4 h of footstep bouts
        ),
        (
            "solar-diurnal",
            ScenarioMatrix::new("off-solar-diurnal", 0x0FF3)
                .mixes(vec![TaskMix::synthetic("uni", 1, 3, 23)])
                .harvesters(vec![HarvesterSpec::SolarDiurnal { eta: 0.4 }])
                .capacitors_mf(vec![50.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .duration_ms(86_400_000.0), // one full day/night cycle
        ),
        (
            // Rich solar, big capacitor, sparse releases: the MCU stays
            // on and idle for most of the hour, so the on-phase idle
            // fast-forward (dark stretches bulked, gate/JIT/deadline
            // budgets honored) carries the row.
            "onidle-solar",
            ScenarioMatrix::new("onidle-solar", 0x0FF4)
                .mixes(vec![TaskMix::from_tasks(
                    "slow",
                    vec![synthetic_task(0, 3, 5_000.0, 10_000.0, 40, 0x51)],
                )])
                .harvesters(vec![HarvesterSpec::Markov {
                    kind: HarvesterKind::Solar,
                    on_power_mw: 350.0,
                    q: 0.97,
                    duty: 0.5,
                    eta: 0.5,
                }])
                .capacitors_mf(vec![50.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .precharge(true)
                .reps(2)
                .duration_ms(3_600_000.0),
        ),
        (
            // Short periods with 3x deadlines on a starved RF harvester:
            // jobs queue up and ride across brown-outs, so the off-phase
            // loop must track the believed next deadline — through a
            // Tier-3 CHRT clock's constant post-reboot skew — instead of
            // assuming an empty queue.
            "rf-queued",
            ScenarioMatrix::new("rf-queued", 0x0FF5)
                .mixes(vec![TaskMix::from_tasks(
                    "queued",
                    vec![synthetic_task(0, 2, 1_000.0, 3_000.0, 40, 0x52)],
                )])
                .harvesters(vec![HarvesterSpec::Markov {
                    kind: HarvesterKind::Rf,
                    on_power_mw: 90.0,
                    q: 0.97,
                    duty: 0.12,
                    eta: 0.38,
                }])
                .capacitors_mf(vec![10.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .faults(vec![
                    FaultPlan::none().with_clock(ClockSpec::Chrt(ChrtTier::Tier3))
                ])
                .reps(2)
                .duration_ms(3_600_000.0),
        ),
    ];
    let mut off_rows: Vec<(String, usize, f64, f64, f64, f64)> = Vec::new();
    for (name, m) in &off_matrices {
        let cells = m.len();
        // Best of two timed runs per leg: the floor below is a hard CI
        // gate, so a single descheduled run must not fake a regression.
        let timed = |run: &dyn Fn() -> zygarde::sim::sweep::SweepReport| {
            let t0 = Instant::now();
            let report = run();
            let dt1 = t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let _ = run();
            (report, dt1.min(t0.elapsed().as_secs_f64()))
        };
        let (fast, fast_dt) = timed(&|| run_matrix(m, 1));
        let (reference, ref_dt) = timed(&|| run_matrix_reference(m, 1));
        assert_eq!(
            fast.json_string(),
            reference.json_string(),
            "{name}: fast engine diverged from the reference stepper"
        );
        let fast_rate = cells as f64 / fast_dt;
        let ref_rate = cells as f64 / ref_dt;
        let speedup = ref_dt / fast_dt;
        println!(
            "off {name:<14} {fast_rate:>8.2} scenarios/s fast ({fast_dt:.3} s)  \
             {ref_rate:>8.2}/s reference ({ref_dt:.3} s)  {speedup:.2}x, byte-identical"
        );
        off_rows.push((name.to_string(), cells, m.duration_ms, fast_rate, ref_rate, speedup));
    }

    // --- NVM commit-policy rows: the commit path rides the fragment hot
    // loop, so per-policy throughput is tracked alongside the thread scaling.
    println!();
    let policies = [
        NvmSpec::ideal(),
        NvmSpec::fram_every_fragment(),
        NvmSpec::fram_unit_boundary(),
        NvmSpec::fram_jit(),
    ];
    let mut nvm_rows: Vec<(String, f64, f64)> = Vec::new();
    for &spec in &policies {
        let m = matrix.clone().nvms(vec![spec]);
        let t0 = Instant::now();
        let report = run_matrix(&m, 4);
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        println!(
            "nvm {:<10} {:>8.1} scenarios/s  ({dt:.3} s, {} commits, {} lost fragments)",
            spec.label(),
            rate,
            report.summary.commits,
            report.summary.lost_fragments
        );
        nvm_rows.push((spec.label(), rate, dt));
    }

    // --- telemetry overhead: traced (null sink) vs untraced --------------
    // One binary cannot time its own pre-telemetry build, so the row
    // measures the strictly MORE expensive enabled path — a counting sink
    // attached, every event constructed and recorded — against the
    // disabled path (`trace = None`, one branch per would-be emission).
    // Gating that ratio under the committed `max_overhead` therefore
    // upper-bounds the disabled-path cost the telemetry layer claims is
    // ~zero. Both legs must also reproduce the reference report byte for
    // byte: tracing is out-of-band or this bench fails before it times.
    println!();
    let scenarios = matrix.expand();
    let timed_cells = |run: &dyn Fn() -> Vec<CellResult>| {
        let t0 = Instant::now();
        let cells = run();
        let dt1 = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let _ = run();
        (cells, dt1.min(t0.elapsed().as_secs_f64()))
    };
    let (untraced_cells, untraced_dt) =
        timed_cells(&|| scenarios.iter().map(run_scenario).collect());
    let events_seen = Rc::new(Cell::new(0u64));
    let (traced_cells, traced_dt) = timed_cells(&|| {
        scenarios
            .iter()
            .map(|sc| run_scenario_with_sink(sc, Box::new(CountingSink::new(events_seen.clone()))))
            .collect()
    });
    let untraced_report = SweepReport::new(&matrix.name, matrix.seed, untraced_cells);
    let traced_report = SweepReport::new(&matrix.name, matrix.seed, traced_cells);
    assert_eq!(
        untraced_report.json_string(),
        reference,
        "trace bench untraced leg diverged from the in-process reference"
    );
    assert_eq!(
        traced_report.json_string(),
        reference,
        "tracing changed the report bytes — the sink is not out-of-band"
    );
    // The counter accumulated over both best-of-two passes.
    let trace_events = events_seen.get() / 2;
    let trace_overhead = traced_dt / untraced_dt;
    println!(
        "trace   untraced {untraced_dt:.3} s  traced(null-sink) {traced_dt:.3} s  \
         overhead {trace_overhead:.3}x  ({trace_events} events/run), byte-identical"
    );

    // --- metrics-registry overhead: profiled vs disabled -----------------
    // Same structure as the trace row: the bench times the strictly MORE
    // expensive enabled path — a registry attached, every hot-loop counter
    // bumped and every fast-forward jump binned — against the disabled
    // path already timed above (`registry = None`, one branch per
    // would-be bump). Gating the ratio under the committed `max_overhead`
    // upper-bounds the disabled-path cost. The profiled leg must also
    // reproduce the reference report byte for byte: the registry is a
    // passive observer or this bench fails before it times.
    let merged_reg = RefCell::new(Registry::new());
    let (profiled_cells, profiled_dt) = timed_cells(&|| {
        let mut acc = Registry::new();
        let cells: Vec<CellResult> = scenarios
            .iter()
            .map(|sc| {
                let (cell, reg) = run_scenario_profiled(sc);
                acc.merge(&reg);
                cell
            })
            .collect();
        *merged_reg.borrow_mut() = acc;
        cells
    });
    let profiled_report = SweepReport::new(&matrix.name, matrix.seed, profiled_cells);
    assert_eq!(
        profiled_report.json_string(),
        reference,
        "attaching a registry changed the report bytes — the registry is not a passive observer"
    );
    let merged_reg = merged_reg.into_inner();
    assert!(!merged_reg.is_zero(), "profiled run accumulated no metrics");
    let registry_commits = merged_reg.get(Counter::Commits);
    let registry_ff_jumps =
        merged_reg.get(Counter::FfOffJumps) + merged_reg.get(Counter::FfOnIdleJumps);
    let registry_overhead = profiled_dt / untraced_dt;
    println!(
        "registry disabled {untraced_dt:.3} s  profiled {profiled_dt:.3} s  \
         overhead {registry_overhead:.3}x  ({registry_commits} commits, \
         {registry_ff_jumps} ff jumps), byte-identical"
    );

    // --- machine-readable trajectory ------------------------------------
    let out = obj(vec![
        ("bench", Value::Str("bench_sweep".to_string())),
        ("scenarios", Value::Num(n as f64)),
        ("duration_ms", Value::Num(duration_ms)),
        ("reps", Value::Num(reps as f64)),
        (
            "threads",
            Value::Arr(
                runs.iter()
                    .map(|(threads, rate, secs, _)| {
                        obj(vec![
                            ("threads", Value::Num(*threads as f64)),
                            ("scenarios_per_s", Value::Num(*rate)),
                            ("secs", Value::Num(*secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "sharded",
            Value::Arr(
                shard_rows
                    .iter()
                    .map(|(procs, rate, secs)| {
                        obj(vec![
                            ("processes", Value::Num(*procs as f64)),
                            ("scenarios_per_s", Value::Num(*rate)),
                            ("secs", Value::Num(*secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "serve",
            Value::Arr(
                serve_rows
                    .iter()
                    .map(|(workers, rate, secs)| {
                        obj(vec![
                            ("workers", Value::Num(*workers as f64)),
                            ("scenarios_per_s", Value::Num(*rate)),
                            ("secs", Value::Num(*secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "offphase",
            Value::Arr(
                off_rows
                    .iter()
                    .map(|(name, cells, duration_ms, fast_rate, ref_rate, speedup)| {
                        obj(vec![
                            ("matrix", Value::Str(name.clone())),
                            ("scenarios", Value::Num(*cells as f64)),
                            ("duration_ms", Value::Num(*duration_ms)),
                            ("scenarios_per_s", Value::Num(*fast_rate)),
                            ("reference_scenarios_per_s", Value::Num(*ref_rate)),
                            ("speedup", Value::Num(*speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "trace",
            Value::Arr(vec![obj(vec![
                ("matrix", Value::Str("bench".to_string())),
                ("scenarios", Value::Num(n as f64)),
                ("duration_ms", Value::Num(duration_ms)),
                ("untraced_secs", Value::Num(untraced_dt)),
                ("traced_secs", Value::Num(traced_dt)),
                ("overhead", Value::Num(trace_overhead)),
                ("events", Value::Num(trace_events as f64)),
            ])]),
        ),
        (
            "registry",
            Value::Arr(vec![obj(vec![
                ("matrix", Value::Str("bench".to_string())),
                ("scenarios", Value::Num(n as f64)),
                ("duration_ms", Value::Num(duration_ms)),
                ("disabled_secs", Value::Num(untraced_dt)),
                ("profiled_secs", Value::Num(profiled_dt)),
                ("overhead", Value::Num(registry_overhead)),
                ("commits", Value::Num(registry_commits as f64)),
                ("ff_jumps", Value::Num(registry_ff_jumps as f64)),
            ])]),
        ),
        (
            "nvm_policies",
            Value::Arr(
                nvm_rows
                    .iter()
                    .map(|(label, rate, secs)| {
                        obj(vec![
                            ("policy", Value::Str(label.clone())),
                            ("scenarios_per_s", Value::Num(*rate)),
                            ("secs", Value::Num(*secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path =
        std::env::var("SWEEP_BENCH_JSON").unwrap_or_else(|_| "BENCH_sweep.json".to_string());
    std::fs::write(&path, out.to_json()).expect("writing bench json");
    println!("\nwrote {path}");
}
