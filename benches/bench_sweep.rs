//! Sweep-engine throughput: scenarios/sec at 1, 2, 4, and 8 threads over
//! a synthetic 96-scenario matrix (no artifacts needed), cross-checking
//! that every thread count produces the byte-identical report, plus a
//! per-NVM-commit-policy throughput section (the commit path is on the
//! engine's hot loop).
//!
//! Run with `cargo bench --bench bench_sweep`. Scale the workload with
//! SWEEP_BENCH_REPS (default 4 reps → 96 scenarios) and
//! SWEEP_BENCH_DURATION_MS (default 20000 ms of simulated time per cell).
//!
//! Emits a machine-readable `BENCH_sweep.json` (path overridable via
//! SWEEP_BENCH_JSON) so the perf trajectory is tracked across PRs.

use std::collections::BTreeMap;
use std::time::Instant;

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::nvm::NvmSpec;
use zygarde::sim::sweep::{run_matrix, FaultPlan, HarvesterSpec, ScenarioMatrix, TaskMix};
use zygarde::util::json::Value;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

fn main() {
    let reps = env_u64("SWEEP_BENCH_REPS", 4);
    let duration_ms = env_u64("SWEEP_BENCH_DURATION_MS", 20_000) as f64;

    // 2 harvesters × 1 cap × 3 schedulers × 2 faults × reps → 12·reps
    // scenarios, plus a second mix doubling it: 24·reps (96 at default).
    let matrix = ScenarioMatrix::new("bench-sweep", 0xB5EE9)
        .mixes(vec![
            TaskMix::synthetic("uni", 1, 3, 11),
            TaskMix::synthetic("duo", 2, 3, 12),
        ])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 120.0,
                q: 0.9,
                duty: 0.6,
                eta: 0.51,
            },
        ])
        .schedulers(vec![
            SchedulerKind::Zygarde,
            SchedulerKind::EdfMandatory,
            SchedulerKind::Edf,
        ])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none().with_brownouts(2_000.0, 400.0, 250.0),
        ])
        .reps(reps)
        .duration_ms(duration_ms);

    let n = matrix.len();
    println!("bench-sweep: {n} scenarios × {duration_ms} ms simulated each\n");

    let mut runs: Vec<(usize, f64, f64, String)> = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let report = run_matrix(&matrix, threads);
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        let speedup = rate / runs.first().map(|(_, r1, _, _)| *r1).unwrap_or(rate);
        println!(
            "threads {threads}: {:>8.1} scenarios/s  ({dt:.3} s total, {speedup:.2}x vs 1 thread)",
            rate
        );
        runs.push((threads, rate, dt, report.json_string()));
    }
    let reference = runs[0].3.clone();
    for (threads, _, _, json) in &runs[1..] {
        assert_eq!(
            &reference, json,
            "thread count {threads} changed the report — determinism broken"
        );
    }

    // --- NVM commit-policy rows: the commit path rides the fragment hot
    // loop, so per-policy throughput is tracked alongside the thread scaling.
    println!();
    let policies = [
        NvmSpec::ideal(),
        NvmSpec::fram_every_fragment(),
        NvmSpec::fram_unit_boundary(),
        NvmSpec::fram_jit(),
    ];
    let mut nvm_rows: Vec<(String, f64, f64)> = Vec::new();
    for &spec in &policies {
        let m = matrix.clone().nvms(vec![spec]);
        let t0 = Instant::now();
        let report = run_matrix(&m, 4);
        let dt = t0.elapsed().as_secs_f64();
        let rate = n as f64 / dt;
        println!(
            "nvm {:<10} {:>8.1} scenarios/s  ({dt:.3} s, {} commits, {} lost fragments)",
            spec.label(),
            rate,
            report.summary.commits,
            report.summary.lost_fragments
        );
        nvm_rows.push((spec.label(), rate, dt));
    }

    // --- machine-readable trajectory ------------------------------------
    let out = obj(vec![
        ("bench", Value::Str("bench_sweep".to_string())),
        ("scenarios", Value::Num(n as f64)),
        ("duration_ms", Value::Num(duration_ms)),
        ("reps", Value::Num(reps as f64)),
        (
            "threads",
            Value::Arr(
                runs.iter()
                    .map(|(threads, rate, secs, _)| {
                        obj(vec![
                            ("threads", Value::Num(*threads as f64)),
                            ("scenarios_per_s", Value::Num(*rate)),
                            ("secs", Value::Num(*secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "nvm_policies",
            Value::Arr(
                nvm_rows
                    .iter()
                    .map(|(label, rate, secs)| {
                        obj(vec![
                            ("policy", Value::Str(label.clone())),
                            ("scenarios_per_s", Value::Num(*rate)),
                            ("secs", Value::Num(*secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path =
        std::env::var("SWEEP_BENCH_JSON").unwrap_or_else(|_| "BENCH_sweep.json".to_string());
    std::fs::write(&path, out.to_json()).expect("writing bench json");
    println!("\nwrote {path}");
}
