//! Benchmarks for the scheduler experiments (Figs. 17–20) and the L3 hot
//! path: one end-to-end simulation bench per dataset×scheduler plus the
//! micro-benchmarks the §Perf log tracks (priority evaluation, queue pick,
//! k-means classify, engine fragment throughput).
//!
//! Run with `cargo bench` (budget via BENCH_BUDGET_MS, default 700 ms per
//! benchmark). Each end-to-end bench also regenerates the figure's rows.

use std::sync::Arc;

use zygarde::coordinator::priority::{zeta_intermittent, EnergyView, PriorityParams};
use zygarde::coordinator::sched::{Scheduler, SchedulerKind};
use zygarde::coordinator::task::Job;
use zygarde::dnn::network::Network;
use zygarde::dnn::trace::compute_traces;
use zygarde::exp::schedule;
use zygarde::sim::workload::task_from_network;
use zygarde::util::bench::Bencher;
use zygarde::util::rng::Pcg32;

fn main() {
    let b = Bencher::default();
    if !zygarde::artifacts_root().join("mnist/meta.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // --- micro: priority function -------------------------------------
    let net = Network::load(&zygarde::artifacts_root().join("mnist")).unwrap();
    let traces = Arc::new(compute_traces(&net, None));
    let task = task_from_network(0, &net, 3000.0, 6000.0, Some(traces.clone()));
    let params = PriorityParams::new(6000.0, 30.0);
    let mut rng = Pcg32::seeded(1);
    let jobs: Vec<Job> = (0..64)
        .map(|i| {
            let mut j = Job::new(&task, i, rng.f64() * 1000.0, i as usize % task.traces.len());
            j.utility = rng.f32() * 20.0;
            j
        })
        .collect();
    let view = EnergyView { e_curr_mj: 120.0, e_opt_mj: 127.0, e_man_mj: 0.8, eta: 0.71 };
    b.run_throughput("priority/zeta_I (64 jobs)", 64.0, "evals/s", || {
        let mut acc = 0f64;
        for j in &jobs {
            acc += zeta_intermittent(j, 500.0, params, &view);
        }
        acc
    })
    .report();

    // --- micro: scheduler pick over a full queue ----------------------
    for kind in [SchedulerKind::Zygarde, SchedulerKind::Edf, SchedulerKind::EdfMandatory] {
        let mut sched = Scheduler::new(kind, params);
        let queue = jobs[..3.min(jobs.len())].to_vec(); // paper's queue size
        b.run(&format!("pick/{} (queue=3)", kind.name()), || {
            sched.pick(&queue, 500.0, &view)
        })
        .report();
    }

    // --- end-to-end: one cell per dataset x scheduler ------------------
    // Fig. 17-20 shape at bench-scale job counts; throughput = simulated
    // jobs per wall-clock second (the §Perf headline for L3).
    for ds in ["mnist", "esc10", "cifar100", "vww"] {
        for kind in schedule::SCHEDULERS {
            let n_jobs = 40u64;
            let r = b.run_throughput(
                &format!("sim/{ds}/{}/S6 ({n_jobs} jobs)", kind.name()),
                n_jobs as f64,
                "jobs/s",
                || {
                    let cells = schedule::run(ds, &[6], Some(n_jobs), 99);
                    cells.into_iter().next().unwrap().metrics.scheduled
                },
            );
            r.report();
        }
    }

    // --- end-to-end: the VWW 40k-job figure at full scale, once --------
    let t0 = std::time::Instant::now();
    let cells = schedule::run("vww", &[6], Some(4000), 7);
    let dt = t0.elapsed().as_secs_f64();
    let m = &cells.iter().find(|c| c.scheduler == SchedulerKind::Zygarde).unwrap().metrics;
    println!(
        "bench sim/vww/zygarde/S6 full-scale-slice: 3x4000 jobs in {dt:.2}s \
         ({:.0} jobs/s; scheduled {:.1}%, fragments {})",
        3.0 * 4000.0 / dt,
        100.0 * m.event_scheduled_rate(),
        m.fragments
    );
}
